//! Graph coloring with multi-phase encoding — exploiting the ONN's
//! ability to "surpass binary limitations" (paper section 1): K colors
//! map to K equally spaced phase sectors; antiferromagnetic coupling
//! pushes adjacent nodes into different sectors.  The reduction lives in
//! `solver::reductions::coloring` (an [`crate::solver::IsingProblem`]
//! with `sectors = k`), the search in the annealed replica portfolio;
//! this file owns the sector decoder and the greedy recolor polish.

use crate::apps::maxcut::Graph;
use crate::onn::phase::wrap;
use crate::solver::anneal::Schedule;
use crate::solver::portfolio::{solve_with, EngineSelect, PortfolioParams};
use crate::solver::reductions;

/// Decode a phase into one of `k` color sectors (nearest sector center).
/// `phi` is wrapped into `[0, P)` first, so negative or unwrapped phases
/// decode correctly instead of falling through a negative-float ->
/// `usize` cast.
pub fn phase_to_color(phi: i32, p: i32, k: usize) -> usize {
    let phi = wrap(phi, p);
    let sector = p as f64 / k as f64;
    let idx = ((phi as f64 + sector / 2.0) / sector).floor() as usize;
    idx % k
}

/// Number of monochromatic (conflicting) edges under a coloring.
pub fn conflicts(graph: &Graph, colors: &[usize]) -> usize {
    graph
        .edges
        .iter()
        .filter(|(i, j, _)| colors[*i] == colors[*j])
        .count()
}

#[derive(Debug, Clone)]
pub struct ColoringResult {
    pub colors: Vec<usize>,
    pub conflicts: usize,
    pub restarts_used: usize,
}

/// Greedy recolor polish: move each vertex to a strictly
/// less-conflicting color until a sweep changes nothing.  Total
/// conflicts strictly decrease per move (bounded by the edge count), so
/// the quadratic sweep cap guarantees termination at a local optimum.
fn recolor_polish(graph: &Graph, k: usize, colors: &mut [usize]) {
    let adj = graph.adjacency();
    for _ in 0..(2 * graph.n * graph.n + 16) {
        let mut changed = false;
        for v in 0..graph.n {
            let mut per_color = vec![0usize; k];
            for &(u, _) in &adj[v] {
                per_color[colors[u]] += 1;
            }
            let best = (0..k).min_by_key(|&c| per_color[c]).unwrap_or(0);
            if per_color[best] < per_color[colors[v]] {
                colors[v] = best;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
}

/// ONN k-coloring: the sector-encoded reduction solved by the annealed
/// replica portfolio; every replica's final phase state is decoded and
/// recolor-polished, and the fewest-conflicts coloring wins.
pub fn solve_onn(
    graph: &Graph,
    k: usize,
    restarts: usize,
    max_periods: usize,
    seed: u64,
) -> ColoringResult {
    solve_onn_with(graph, k, restarts, max_periods, seed, EngineSelect::Native)
}

/// [`solve_onn`] on an explicitly selected engine fabric (native or
/// row-sharded — the answer is bit-identical either way).
pub fn solve_onn_with(
    graph: &Graph,
    k: usize,
    restarts: usize,
    max_periods: usize,
    seed: u64,
    select: EngineSelect,
) -> ColoringResult {
    assert!(
        (2..=16).contains(&k),
        "k = {k} outside 2..=16 (the 16-step phase wheel caps the sector count)"
    );
    if graph.n == 0 {
        return ColoringResult {
            colors: Vec::new(),
            conflicts: 0,
            restarts_used: 0,
        };
    }
    let problem = reductions::coloring(graph, k);
    let params = PortfolioParams {
        replicas: restarts.max(1),
        max_periods: max_periods.max(8),
        schedule: Schedule::Geometric {
            start: 0.35,
            factor: 0.7,
        },
        seed,
        polish: false, // binary polish does not apply to sectors
        ..Default::default()
    };
    let out = solve_with(&problem, &params, select)
        .expect("portfolio on a validated coloring reduction");
    // Decode on the same phase wheel the portfolio's engine ran on.
    let p = crate::onn::config::NetworkConfig::paper(graph.n).period() as i32;
    let mut best = ColoringResult {
        colors: vec![0; graph.n],
        conflicts: usize::MAX,
        restarts_used: 0,
    };
    // Rank candidates by the true objective (conflict count): the best
    // tracked phase state plus every replica's final state.
    let candidates = std::iter::once(&out.best_phases).chain(out.replica_phases.iter());
    for (r, phases) in candidates.enumerate() {
        let mut colors: Vec<usize> = phases
            .iter()
            .map(|&phi| phase_to_color(phi, p, k))
            .collect();
        recolor_polish(graph, k, &mut colors);
        let c = conflicts(graph, &colors);
        if c < best.conflicts {
            best = ColoringResult {
                colors,
                conflicts: c,
                restarts_used: r.max(1),
            };
            if c == 0 {
                break;
            }
        }
    }
    best
}

/// Greedy baseline: color vertices in degree order with the first free
/// color (classic Welsh-Powell flavour).
pub fn solve_greedy(graph: &Graph, k: usize) -> ColoringResult {
    let n = graph.n;
    let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
    for &(i, j, _) in &graph.edges {
        adj[i].push(j);
        adj[j].push(i);
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&v| std::cmp::Reverse(adj[v].len()));
    let mut colors = vec![usize::MAX; n];
    for &v in &order {
        let mut used = vec![false; k];
        for &u in &adj[v] {
            if colors[u] != usize::MAX {
                used[colors[u]] = true;
            }
        }
        colors[v] = used.iter().position(|&b| !b).unwrap_or(0);
    }
    let c = conflicts(graph, &colors);
    ColoringResult {
        colors,
        conflicts: c,
        restarts_used: 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cycle(n: usize) -> Graph {
        Graph {
            n,
            edges: (0..n).map(|i| (i, (i + 1) % n, 1)).collect(),
        }
    }

    #[test]
    fn phase_to_color_sectors() {
        // P=16, k=2: sector centers at 0 and 8.
        assert_eq!(phase_to_color(0, 16, 2), 0);
        assert_eq!(phase_to_color(3, 16, 2), 0);
        assert_eq!(phase_to_color(8, 16, 2), 1);
        assert_eq!(phase_to_color(15, 16, 2), 0); // wraps to sector 0
        // k=4: centers 0, 4, 8, 12.
        assert_eq!(phase_to_color(4, 16, 4), 1);
        assert_eq!(phase_to_color(13, 16, 4), 3);
    }

    #[test]
    fn phase_to_color_wraps_negative_and_overflow() {
        // Negative phases must wrap, not collapse through a
        // negative-float -> usize cast.
        assert_eq!(phase_to_color(-1, 16, 2), phase_to_color(15, 16, 2));
        assert_eq!(phase_to_color(-8, 16, 2), phase_to_color(8, 16, 2));
        assert_eq!(phase_to_color(-5, 16, 4), phase_to_color(11, 16, 4));
        // Phases beyond one period wrap the same way.
        assert_eq!(phase_to_color(16, 16, 4), phase_to_color(0, 16, 4));
        assert_eq!(phase_to_color(35, 16, 4), phase_to_color(3, 16, 4));
        // Exhaustive: every wrapped phase matches its canonical twin.
        for k in 2..=8 {
            for phi in -48..48 {
                assert_eq!(
                    phase_to_color(phi, 16, k),
                    phase_to_color(phi.rem_euclid(16), 16, k),
                    "phi={phi} k={k}"
                );
            }
        }
    }

    #[test]
    fn phase_to_color_boundary_phases() {
        // P=16, k=3: sector width 16/3; boundaries at 2.67, 8, 13.33.
        assert_eq!(phase_to_color(2, 16, 3), 0);
        assert_eq!(phase_to_color(3, 16, 3), 1);
        assert_eq!(phase_to_color(8, 16, 3), 1); // exactly on the boundary
        assert_eq!(phase_to_color(13, 16, 3), 2);
        assert_eq!(phase_to_color(15, 16, 3), 0); // wraps to sector 0
    }

    #[test]
    fn even_cycle_two_colorable() {
        let g = cycle(8);
        let res = solve_onn(&g, 2, 20, 64, 11);
        assert_eq!(res.conflicts, 0, "colors: {:?}", res.colors);
    }

    #[test]
    fn greedy_handles_even_cycle() {
        let res = solve_greedy(&cycle(10), 2);
        assert_eq!(res.conflicts, 0);
    }

    #[test]
    fn odd_cycle_needs_three_colors_greedy() {
        let res2 = solve_greedy(&cycle(5), 2);
        assert!(res2.conflicts >= 1);
        let res3 = solve_greedy(&cycle(5), 3);
        assert_eq!(res3.conflicts, 0);
    }

    #[test]
    fn onn_beats_or_matches_random_coloring() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(21);
        let g = Graph::random(20, 0.25, &mut rng);
        let onn = solve_onn(&g, 2, 15, 96, 5);
        // random baseline: expected half the edges conflict
        let rand_conflicts = g.edges.len() / 2;
        assert!(
            onn.conflicts <= rand_conflicts,
            "ONN {} vs random {}",
            onn.conflicts,
            rand_conflicts
        );
    }

    #[test]
    fn recolor_polish_never_increases_conflicts() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(22);
        for k in [2usize, 3, 4] {
            let g = Graph::random(16, 0.3, &mut rng);
            let mut colors: Vec<usize> =
                (0..g.n).map(|_| rng.usize_below(k)).collect();
            let before = conflicts(&g, &colors);
            recolor_polish(&g, k, &mut colors);
            assert!(conflicts(&g, &colors) <= before);
            assert!(colors.iter().all(|&c| c < k));
        }
    }
}
