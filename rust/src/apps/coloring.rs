//! Graph coloring with multi-phase encoding — exploiting the ONN's
//! ability to "surpass binary limitations" (paper section 1): K colors
//! map to K equally spaced phase sectors; antiferromagnetic coupling
//! pushes adjacent nodes into different sectors.

use crate::apps::maxcut::Graph;
use crate::onn::config::NetworkConfig;
use crate::onn::dynamics::FunctionalEngine;
use crate::onn::weights::WeightMatrix;
use crate::util::rng::Rng;

/// Decode a phase into one of `k` color sectors (nearest sector center).
pub fn phase_to_color(phi: i32, p: i32, k: usize) -> usize {
    let sector = p as f64 / k as f64;
    let idx = ((phi as f64 + sector / 2.0) / sector).floor() as usize;
    idx % k
}

/// Number of monochromatic (conflicting) edges under a coloring.
pub fn conflicts(graph: &Graph, colors: &[usize]) -> usize {
    graph
        .edges
        .iter()
        .filter(|(i, j, _)| colors[*i] == colors[*j])
        .count()
}

#[derive(Debug, Clone)]
pub struct ColoringResult {
    pub colors: Vec<usize>,
    pub conflicts: usize,
    pub restarts_used: usize,
}

/// ONN k-coloring: antiferromagnetic unit couplings on edges, random
/// phase initial conditions, decode sectors after settling; keep the
/// best restart.
pub fn solve_onn(graph: &Graph, k: usize, restarts: usize, max_periods: usize, seed: u64) -> ColoringResult {
    assert!(k >= 2);
    let cfg = NetworkConfig::paper(graph.n);
    let p = cfg.period() as i32;
    let n = graph.n;
    let mut master = vec![0f32; n * n];
    for &(i, j, w) in &graph.edges {
        master[i * n + j] = -(w as f32);
        master[j * n + i] = -(w as f32);
    }
    let w = WeightMatrix::quantize(&master, n, &cfg);
    let mut eng = FunctionalEngine::new(cfg, w);
    let mut rng = Rng::new(seed);
    let mut best = ColoringResult {
        colors: vec![0; n],
        conflicts: usize::MAX,
        restarts_used: 0,
    };
    for r in 0..restarts {
        let init: Vec<i32> = (0..n).map(|_| rng.range_i64(0, p as i64) as i32).collect();
        let out = eng.run_to_settle(&init, max_periods);
        let colors: Vec<usize> = out
            .phases
            .iter()
            .map(|&phi| phase_to_color(phi, p, k))
            .collect();
        let c = conflicts(graph, &colors);
        if c < best.conflicts {
            best = ColoringResult {
                colors,
                conflicts: c,
                restarts_used: r + 1,
            };
            if c == 0 {
                break;
            }
        }
    }
    best
}

/// Greedy baseline: color vertices in degree order with the first free
/// color (classic Welsh-Powell flavour).
pub fn solve_greedy(graph: &Graph, k: usize) -> ColoringResult {
    let n = graph.n;
    let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
    for &(i, j, _) in &graph.edges {
        adj[i].push(j);
        adj[j].push(i);
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&v| std::cmp::Reverse(adj[v].len()));
    let mut colors = vec![usize::MAX; n];
    for &v in &order {
        let mut used = vec![false; k];
        for &u in &adj[v] {
            if colors[u] != usize::MAX {
                used[colors[u]] = true;
            }
        }
        colors[v] = used.iter().position(|&b| !b).unwrap_or(0);
    }
    let c = conflicts(graph, &colors);
    ColoringResult {
        colors,
        conflicts: c,
        restarts_used: 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cycle(n: usize) -> Graph {
        Graph {
            n,
            edges: (0..n).map(|i| (i, (i + 1) % n, 1)).collect(),
        }
    }

    #[test]
    fn phase_to_color_sectors() {
        // P=16, k=2: sector centers at 0 and 8.
        assert_eq!(phase_to_color(0, 16, 2), 0);
        assert_eq!(phase_to_color(3, 16, 2), 0);
        assert_eq!(phase_to_color(8, 16, 2), 1);
        assert_eq!(phase_to_color(15, 16, 2), 0); // wraps to sector 0
        // k=4: centers 0, 4, 8, 12.
        assert_eq!(phase_to_color(4, 16, 4), 1);
        assert_eq!(phase_to_color(13, 16, 4), 3);
    }

    #[test]
    fn even_cycle_two_colorable() {
        let g = cycle(8);
        let res = solve_onn(&g, 2, 20, 64, 11);
        assert_eq!(res.conflicts, 0, "colors: {:?}", res.colors);
    }

    #[test]
    fn greedy_handles_even_cycle() {
        let res = solve_greedy(&cycle(10), 2);
        assert_eq!(res.conflicts, 0);
    }

    #[test]
    fn odd_cycle_needs_three_colors_greedy() {
        let res2 = solve_greedy(&cycle(5), 2);
        assert!(res2.conflicts >= 1);
        let res3 = solve_greedy(&cycle(5), 3);
        assert_eq!(res3.conflicts, 0);
    }

    #[test]
    fn onn_beats_or_matches_random_coloring() {
        let mut rng = Rng::new(21);
        let g = Graph::random(20, 0.25, &mut rng);
        let onn = solve_onn(&g, 2, 15, 96, 5);
        // random baseline: expected half the edges conflict
        let rand_conflicts = g.edges.len() / 2;
        assert!(
            onn.conflicts <= rand_conflicts,
            "ONN {} vs random {}",
            onn.conflicts,
            rand_conflicts
        );
    }
}
