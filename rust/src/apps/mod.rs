//! The paper's future-work applications ("larger network sizes can be
//! benchmarked using ... especially combinatorial optimization
//! problems"): the ONN as an oscillatory Ising machine.

pub mod coloring;
pub mod maxcut;
