//! Max-cut on the ONN-as-Ising-machine path — now a thin adapter over
//! the `solver` subsystem: the reduction lives in
//! `solver::reductions::max_cut`, the search in the annealed batched
//! replica portfolio (`solver::portfolio`), and the baseline in the
//! generic simulated annealer (`solver::sa`).  This file owns only the
//! graph-flavored entry points and decoders the CLI/examples use.

pub use crate::solver::graph::Graph;

use crate::onn::config::NetworkConfig;
use crate::onn::weights::WeightMatrix;
use crate::solver::anneal::Schedule;
use crate::solver::portfolio::{solve_native, PortfolioParams};
use crate::solver::reductions::max_cut;
use crate::solver::sa;

/// Result of one solver run.
#[derive(Debug, Clone)]
pub struct CutResult {
    pub spins: Vec<i8>,
    pub cut: i64,
    /// Engine chunk-periods (ONN) or sweeps (SA) spent.
    pub effort: usize,
}

/// Embed the graph into ONN weights: `W_ij = -w_ij`, quantized.
pub fn embed(graph: &Graph, cfg: &NetworkConfig) -> WeightMatrix {
    max_cut(graph).embed(cfg)
}

/// ONN max-cut: the annealed replica portfolio on the batched native
/// engine.  `restarts` random-init replicas run as one batch for up to
/// `max_periods` periods under a geometric phase-noise ramp; every
/// replica gets the deterministic greedy readout polish, and the best
/// cut wins.
pub fn solve_onn(graph: &Graph, restarts: usize, max_periods: usize, seed: u64) -> CutResult {
    if graph.n == 0 {
        return CutResult {
            spins: Vec::new(),
            cut: 0,
            effort: 0,
        };
    }
    let problem = max_cut(graph);
    let params = PortfolioParams {
        replicas: restarts.max(1),
        max_periods: max_periods.max(8),
        schedule: Schedule::Geometric {
            start: 0.5,
            factor: 0.75,
        },
        seed,
        ..Default::default()
    };
    let out = solve_native(&problem, &params)
        .expect("native portfolio on a validated max-cut reduction");
    CutResult {
        cut: graph.cut_value(&out.best_spins),
        spins: out.best_spins,
        effort: out.periods,
    }
}

/// Simulated-annealing baseline on the same reduction.
pub fn solve_sa(graph: &Graph, sweeps: usize, seed: u64) -> CutResult {
    let problem = max_cut(graph);
    let r = sa::anneal(&problem, sweeps, seed);
    CutResult {
        cut: graph.cut_value(&r.spins),
        spins: r.spins,
        effort: sweeps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn onn_solves_bipartite_graph_optimally() {
        // K_{3,3}: odd-part complete bipartite graphs have no
        // non-optimal strict local minima, so the portfolio's readout
        // polish guarantees the full cut.
        let g = Graph::complete_bipartite(3, 3);
        let res = solve_onn(&g, 10, 64, 123);
        assert_eq!(res.cut, 9, "spins: {:?}", res.spins);
    }

    #[test]
    fn onn_competitive_with_sa_on_random_graphs() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(9);
        let g = Graph::random(24, 0.3, &mut rng);
        let onn = solve_onn(&g, 20, 128, 1);
        let sa = solve_sa(&g, 200, 2);
        assert!(
            onn.cut as f64 >= 0.9 * sa.cut as f64,
            "ONN {} vs SA {}",
            onn.cut,
            sa.cut
        );
    }

    #[test]
    fn sa_reaches_triangle_optimum() {
        // Triangle: max cut = 2.
        let g = Graph {
            n: 3,
            edges: vec![(0, 1, 1), (1, 2, 1), (0, 2, 1)],
        };
        let res = solve_sa(&g, 50, 3);
        assert_eq!(res.cut, 2);
    }

    #[test]
    fn embed_is_antiferromagnetic_symmetric() {
        let g = Graph {
            n: 3,
            edges: vec![(0, 1, 2), (1, 2, 1)],
        };
        let w = embed(&g, &NetworkConfig::paper(3));
        assert!(w.is_symmetric());
        assert!(w.get(0, 1) < 0);
        assert_eq!(w.get(0, 2), 0);
        // strongest edge saturates the quantized range
        assert_eq!(w.get(0, 1), -15);
    }

    #[test]
    fn onn_results_are_single_flip_optimal() {
        // The portfolio's readout polish guarantees no single spin flip
        // can improve the returned cut (the local-optimality contract
        // the old async relaxation provided).
        use crate::solver::reductions::max_cut;
        use crate::solver::sa::is_local_minimum;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(77);
        let g = Graph::random(14, 0.35, &mut rng);
        let res = solve_onn(&g, 5, 64, 8);
        assert!(is_local_minimum(&max_cut(&g), &res.spins));
    }
}
