//! Max-cut on the ONN-as-Ising-machine path, with a simulated-annealing
//! baseline (the paper's Discussion names combinatorial optimization as
//! the next step for the scaled-up hybrid architecture).
//!
//! Mapping: graph edge (i, j, w) becomes antiferromagnetic coupling
//! `W_ij = W_ji = -w`; the network's binary phase states then minimize
//! the Ising energy, whose ground state is the maximum cut.  Multi-
//! restart: random binary initial phases per restart, best cut kept.

use crate::onn::config::NetworkConfig;
use crate::onn::weights::WeightMatrix;
use crate::util::rng::Rng;

/// Undirected weighted graph.
#[derive(Debug, Clone)]
pub struct Graph {
    pub n: usize,
    pub edges: Vec<(usize, usize, i32)>,
}

impl Graph {
    /// Erdos-Renyi random graph with unit weights.
    pub fn random(n: usize, edge_prob: f64, rng: &mut Rng) -> Graph {
        let mut edges = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                if rng.f64() < edge_prob {
                    edges.push((i, j, 1));
                }
            }
        }
        Graph { n, edges }
    }

    /// Cut value of a +-1 assignment.
    pub fn cut_value(&self, spins: &[i8]) -> i64 {
        assert_eq!(spins.len(), self.n);
        self.edges
            .iter()
            .filter(|(i, j, _)| spins[*i] != spins[*j])
            .map(|(_, _, w)| *w as i64)
            .sum()
    }

    pub fn total_weight(&self) -> i64 {
        self.edges.iter().map(|(_, _, w)| *w as i64).sum()
    }
}

/// Result of one solver run.
#[derive(Debug, Clone)]
pub struct CutResult {
    pub spins: Vec<i8>,
    pub cut: i64,
    /// Periods (ONN) or sweeps (SA) spent.
    pub effort: usize,
}

/// Embed the graph into ONN weights: `W_ij = -w_ij`, quantized.
pub fn embed(graph: &Graph, cfg: &NetworkConfig) -> WeightMatrix {
    let n = graph.n;
    let mut master = vec![0f32; n * n];
    for &(i, j, w) in &graph.edges {
        master[i * n + j] = -(w as f32);
        master[j * n + i] = -(w as f32);
    }
    WeightMatrix::quantize(&master, n, cfg)
}

/// ONN max-cut solver: multi-restart relaxation with *asynchronous*
/// update ordering.
///
/// Physical coupled oscillators update continuously; the recurrent RTL
/// realizes this as per-oscillator updates at each oscillator's own
/// rising edge, spread across the period.  A fully synchronous update
/// would make dense antiferromagnetic networks flip-flop globally and
/// never settle, so here each restart relaxes the network one
/// oscillator at a time (async Hopfield on the binary phase manifold —
/// equivalent to the period-snap dynamics at phases {0, P/2} by the
/// Hopfield-equivalence property, see onn::dynamics tests).  For small
/// networks the full phase-domain engine cross-checks this in tests.
pub fn solve_onn(graph: &Graph, restarts: usize, max_sweeps: usize, seed: u64) -> CutResult {
    let cfg = NetworkConfig::paper(graph.n);
    let w = embed(graph, &cfg);
    let n = graph.n;
    let mut rng = Rng::new(seed);
    let mut best = CutResult {
        spins: vec![1; n],
        cut: i64::MIN,
        effort: 0,
    };
    let mut effort = 0usize;
    for _ in 0..restarts {
        let mut spins: Vec<i8> = (0..n).map(|_| rng.spin()).collect();
        // local fields h_i = sum_j W_ij s_j
        let mut h: Vec<i32> = (0..n)
            .map(|i| {
                (0..n)
                    .map(|j| w.get(i, j) as i32 * spins[j] as i32)
                    .sum()
            })
            .collect();
        // async relaxation: update oscillators in rising-edge order
        // (binary states form two groups; sweep order rotates so both
        // groups get early updates across sweeps)
        let mut order: Vec<usize> = (0..n).collect();
        for sweep in 0..max_sweeps {
            rng.shuffle(&mut order);
            let mut changed = false;
            for &i in &order {
                let target = if h[i] > 0 {
                    1
                } else if h[i] < 0 {
                    -1
                } else {
                    spins[i] // tie keeps state, like the zero-sum reference rule
                };
                if target != spins[i] {
                    spins[i] = target;
                    changed = true;
                    let si = spins[i] as i32;
                    for j in 0..n {
                        // h_j gains 2 * W_ji * s_i
                        h[j] += 2 * w.get(j, i) as i32 * si;
                    }
                }
            }
            effort = effort.saturating_add(1);
            if !changed {
                let _ = sweep;
                break;
            }
        }
        let cut = graph.cut_value(&spins);
        if cut > best.cut {
            best = CutResult {
                spins,
                cut,
                effort,
            };
        } else {
            best.effort = effort;
        }
    }
    best
}

/// Simulated-annealing baseline (single-spin-flip Metropolis).
pub fn solve_sa(graph: &Graph, sweeps: usize, seed: u64) -> CutResult {
    let n = graph.n;
    let mut rng = Rng::new(seed);
    let mut spins: Vec<i8> = (0..n).map(|_| rng.spin()).collect();
    // Adjacency for O(deg) delta evaluation.
    let mut adj: Vec<Vec<(usize, i32)>> = vec![Vec::new(); n];
    for &(i, j, w) in &graph.edges {
        adj[i].push((j, w));
        adj[j].push((i, w));
    }
    let mut cut = graph.cut_value(&spins);
    let mut best = spins.clone();
    let mut best_cut = cut;
    let (t0, t1) = (2.0f64, 0.05f64);
    for s in 0..sweeps {
        let temp = t0 * (t1 / t0).powf(s as f64 / sweeps.max(1) as f64);
        for _ in 0..n {
            let i = rng.usize_below(n);
            // Flipping i toggles every incident edge's cut membership.
            let delta: i64 = adj[i]
                .iter()
                .map(|&(j, w)| {
                    if spins[i] != spins[j] {
                        -(w as i64)
                    } else {
                        w as i64
                    }
                })
                .sum();
            if delta >= 0 || rng.f64() < (delta as f64 / temp).exp() {
                spins[i] = -spins[i];
                cut += delta;
                if cut > best_cut {
                    best_cut = cut;
                    best.copy_from_slice(&spins);
                }
            }
        }
    }
    CutResult {
        spins: best,
        cut: best_cut,
        effort: sweeps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cut_value_bipartite_complete() {
        // K_{2,2}: optimal cut = all 4 edges.
        let g = Graph {
            n: 4,
            edges: vec![(0, 2, 1), (0, 3, 1), (1, 2, 1), (1, 3, 1)],
        };
        assert_eq!(g.cut_value(&[1, 1, -1, -1]), 4);
        assert_eq!(g.cut_value(&[1, -1, 1, -1]), 2);
    }

    #[test]
    fn onn_solves_bipartite_graph_optimally() {
        // Bipartite graphs have frustration-free Ising embeddings: the
        // ONN must find the full cut.
        let g = Graph {
            n: 6,
            edges: vec![
                (0, 3, 1),
                (0, 4, 1),
                (1, 3, 1),
                (1, 5, 1),
                (2, 4, 1),
                (2, 5, 1),
            ],
        };
        let res = solve_onn(&g, 10, 64, 123);
        assert_eq!(res.cut, 6, "spins: {:?}", res.spins);
    }

    #[test]
    fn onn_competitive_with_sa_on_random_graphs() {
        let mut rng = Rng::new(9);
        let g = Graph::random(24, 0.3, &mut rng);
        let onn = solve_onn(&g, 20, 128, 1);
        let sa = solve_sa(&g, 200, 2);
        assert!(
            onn.cut as f64 >= 0.9 * sa.cut as f64,
            "ONN {} vs SA {}",
            onn.cut,
            sa.cut
        );
    }

    #[test]
    fn sa_reaches_triangle_optimum() {
        // Triangle: max cut = 2.
        let g = Graph {
            n: 3,
            edges: vec![(0, 1, 1), (1, 2, 1), (0, 2, 1)],
        };
        let res = solve_sa(&g, 50, 3);
        assert_eq!(res.cut, 2);
    }

    #[test]
    fn embed_is_antiferromagnetic_symmetric() {
        let g = Graph {
            n: 3,
            edges: vec![(0, 1, 2), (1, 2, 1)],
        };
        let w = embed(&g, &NetworkConfig::paper(3));
        assert!(w.is_symmetric());
        assert!(w.get(0, 1) < 0);
        assert_eq!(w.get(0, 2), 0);
        // strongest edge saturates the quantized range
        assert_eq!(w.get(0, 1), -15);
    }

    #[test]
    fn async_fixed_points_are_phase_engine_fixed_points() {
        // The async relaxation's fixed points must also be fixed points
        // of the full phase-domain dynamics (Hopfield equivalence on the
        // binary manifold).
        use crate::onn::dynamics::FunctionalEngine;
        use crate::onn::phase::spin_to_phase;
        let mut rng = Rng::new(77);
        let g = Graph::random(14, 0.35, &mut rng);
        let res = solve_onn(&g, 5, 64, 8);
        let cfg = NetworkConfig::paper(g.n);
        let w = embed(&g, &cfg);
        let mut eng = FunctionalEngine::new(cfg, w);
        let mut ph: Vec<i32> = res.spins.iter().map(|&s| spin_to_phase(s, 16)).collect();
        let before = ph.clone();
        eng.period_step(&mut ph);
        assert_eq!(ph, before, "async fixed point moved under phase dynamics");
    }

    #[test]
    fn random_graph_edge_count_reasonable() {
        let mut rng = Rng::new(4);
        let g = Graph::random(30, 0.5, &mut rng);
        let max_edges = 30 * 29 / 2;
        assert!(g.edges.len() > max_edges / 4 && g.edges.len() < max_edges * 3 / 4);
    }
}
