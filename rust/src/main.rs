//! `onn-scale` — CLI entry point of the L3 coordinator.
//!
//! Subcommands regenerate every table/figure of the paper, run pattern
//! retrieval end-to-end (through the router -> batcher -> PJRT engine),
//! solve max-cut/coloring on the Ising-machine path, serve the TCP
//! front-end, and cross-validate the PJRT artifacts against the native
//! bit-exact engine.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use onn_scale::coordinator::batcher::BatchPolicy;
use onn_scale::coordinator::server::{serve_tcp, Coordinator, EngineKind, PoolSpec};
use onn_scale::coordinator::stream::serve_evented;
use onn_scale::harness::datasets::benchmark_by_name;
use onn_scale::harness::report::{self, RetrievalReport};
use onn_scale::harness::retrieval::{run_cell, CellStats, Engine, CORRUPTION_LEVELS};
use onn_scale::util::cli::Args;

const USAGE: &str = "\
onn-scale — digital ONN architectures (recurrent vs hybrid), reproduced

USAGE: onn-scale <command> [flags]

Paper reproduction:
  table1 | table2 | table4 | table5     print the corresponding table
  table6 [--trials K] [--ra-engine E] [--ha-engine E] [--sizes a,b]
                                        retrieval accuracy sweep (+table7)
  fig9 | fig10 | fig11 | fig12          print the corresponding figure

Applications:
  retrieve --dataset 7x6 [--corrupt 25] [--engine native|pjrt] [--seed S]
  maxcut [--nodes 32] [--prob 0.3] [--restarts 20] [--seed S]
  coloring [--nodes 16] [--colors 2] [--restarts 20]

Solver (generic Ising/QUBO subsystem, see DESIGN_SOLVER.md):
  solve --problem maxcut|coloring|partition|cover [--nodes 64] [--prob 0.1]
        [--colors 3] [--replicas 32] [--periods 256]
        [--schedule geometric|linear|constant] [--noise 0.6] [--seed S]
        [--shards K]      K=0 auto-selects by size; K>1 forces the
                          sharded multi-device engine (bit-exact)
        [--rtl]           run on the bit-true emulated-hardware engine
                          (cycle-accurate serial MACs; reports the
                          emulated fast-cycle cost); --rtl --shards K>1
                          runs the emulated K-FPGA cluster instead
                          (row-split weight memory, priced phase
                          all-gathers)
        [--weight-bits B] [--phase-bits P]
                          precision sweep point for --rtl solves
                          (B in 3..=8, P in 3..=6; default is the
                          paper's 5-bit weights / 4-bit phases)
        [--trace FILE]    export the solve-lifecycle trace as JSONL
                          (wave/chunk/engine spans, DESIGN_SOLVER.md §9)
  trace-check --path FILE
                          validate a JSONL trace export against the
                          telemetry schema (field presence + monotonic
                          seq/timestamps)
  solve-bench [--sizes 16,32,64,128] [--replicas 32] [--periods 128]
        [--instances 5] [--shards K] [--packed [N]] [--rtl]
        [--rtl-packed] [--rtl-cluster] [--connections [N]] [--sparse]
        [--associative] [--out BENCH_solver.json]
                          quality vs SA + native (and, with --shards,
                          sharded) throughput rows; --packed adds an
                          N-instance (default 6) small-mix row comparing
                          the shared lane-block engine against
                          one-engine-per-request serving; --rtl adds
                          float-native vs bit-true rows (quality +
                          emulated time-to-solution); --rtl-packed adds
                          a lane-bank packed hardware row (an
                          equal-size mix through one shared rtl engine
                          vs one-engine-per-request, exact fast-cycle
                          parity asserted); --rtl-cluster adds an
                          emulated multi-FPGA row (an instance past the
                          single-device fit, per-period all-gather
                          priced; --shards sizes the cluster, default
                          2 devices); --connections adds
                          a connection-scale serving row (sustained
                          solves/sec at N (default 64) concurrent
                          streaming clients, evented front end vs
                          thread-per-connection baseline); --sparse adds
                          dense-vs-CSR fabric rows (bit-exact work,
                          fixed density 0.05 plus a G(n, 4/n) sweep:
                          replica-periods/sec, weight memory, modeled
                          hardware oscillation); --associative adds the
                          online-learning associative-memory row
                          (delta-reprogrammed warm recalls vs cold
                          retrain+rebuild recalls/sec, bit-identity
                          asserted in-harness, plus recall accuracy vs
                          stored load); every run also records
                          latency percentiles and a convergence trace
                          per size
  solve-report [--path BENCH_solver.json]
                          render the recorded solver trajectory next to
                          the paper tables

Ablations (DESIGN.md design choices):
  ablation [--trials 50]                precision vs capacity/accuracy
  capacity [--n 20] [--trials 50]       DO-I vs Hebbian storage capacity
  shard-demo [--n 42] [--shards 4]      multi-device sharding bit-exactness demo

Service / validation:
  serve [--addr 127.0.0.1:7020] --dataset 7x6 [--engine pjrt] [--threads]
                          evented streaming front end by default
                          (mid-anneal progress lines + disconnect
                          cancellation, DESIGN_SOLVER.md §10);
                          --threads keeps thread-per-connection
  crosscheck [--dataset 3x3] [--trials 16]   pjrt vs native bit-exactness
  assoc-smoke [--periods 64]
                          store -> recall -> forget -> recall smoke over
                          one evented TCP connection (asserts each wire
                          reply plus the metrics counters; the
                          associative CI gate)
  info                                        artifact + platform info
";

fn main() {
    let code = match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn run() -> Result<()> {
    let mut args = Args::from_env().map_err(|e| anyhow!(e))?;
    let cmd = args.subcommand.clone().unwrap_or_else(|| "help".to_string());
    match cmd.as_str() {
        "help" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        "table1" => {
            println!("{}", report::table1());
            Ok(())
        }
        "table2" => {
            println!("{}", report::table2());
            Ok(())
        }
        "table4" => {
            println!("{}", report::table4());
            Ok(())
        }
        "table5" => {
            println!("{}", report::table5());
            Ok(())
        }
        "fig9" => {
            println!("{}", report::fig9());
            Ok(())
        }
        "fig10" => {
            println!("{}", report::fig10());
            Ok(())
        }
        "fig11" => {
            println!("{}", report::fig11());
            Ok(())
        }
        "fig12" => {
            println!("{}", report::fig12());
            Ok(())
        }
        "table6" | "table7" => cmd_table67(&mut args),
        "retrieve" => cmd_retrieve(&mut args),
        "maxcut" => cmd_maxcut(&mut args),
        "coloring" => cmd_coloring(&mut args),
        "solve" => cmd_solve(&mut args),
        "trace-check" => cmd_trace_check(&mut args),
        "solve-bench" => cmd_solve_bench(&mut args),
        "solve-report" => cmd_solve_report(&mut args),
        "serve" => cmd_serve(&mut args),
        "crosscheck" => cmd_crosscheck(&mut args),
        "ablation" => cmd_ablation(&mut args),
        "capacity" => cmd_capacity(&mut args),
        "shard-demo" => cmd_shard_demo(&mut args),
        "assoc-smoke" => cmd_assoc_smoke(&mut args),
        "info" => cmd_info(),
        other => Err(anyhow!("unknown command '{other}'\n{USAGE}")),
    }
}

/// Tables 6 + 7: the full retrieval sweep.  RA runs on the cycle-accurate
/// recurrent simulator up to its implementable sizes (<= 48 oscillators,
/// like the paper); HA runs on the selected engine for all sizes.
fn cmd_table67(args: &mut Args) -> Result<()> {
    let trials = args.get_usize("trials", 100)?;
    let seed = args.get_u64("seed", 2025)?;
    let ra_engine = Engine::parse(&args.get_str("ra-engine", "rtl-recurrent"))
        .ok_or_else(|| anyhow!("bad --ra-engine"))?;
    let ha_engine = Engine::parse(&args.get_str("ha-engine", "native"))
        .ok_or_else(|| anyhow!("bad --ha-engine"))?;
    let sizes = args.get_str("sizes", "3x3,5x4,7x6,10x10,22x22");
    args.finish().map_err(|e| anyhow!(e))?;

    let mut cells: Vec<(String, f64, Option<CellStats>, CellStats)> = Vec::new();
    for name in sizes.split(',') {
        let set = benchmark_by_name(name.trim())
            .ok_or_else(|| anyhow!("unknown dataset '{name}'"))?;
        let ra_feasible = set.cfg.n <= 48;
        for pct in CORRUPTION_LEVELS {
            eprintln!(
                "running {name} @ {pct}% ({} trials x {} patterns)...",
                trials,
                set.dataset.patterns.len()
            );
            let ha = run_cell(&set, pct, trials, seed, ha_engine)?;
            let ra = if ra_feasible {
                Some(run_cell(&set, pct, trials, seed, ra_engine)?)
            } else {
                None
            };
            cells.push((set.dataset.name.clone(), pct, ra, ha));
        }
    }
    let rep = RetrievalReport { cells };
    println!("{}", rep.table6());
    println!("{}", rep.table7());
    Ok(())
}

fn cmd_retrieve(args: &mut Args) -> Result<()> {
    use onn_scale::coordinator::job::RetrievalRequest;
    use onn_scale::onn::phase::state_to_spins;
    use onn_scale::util::rng::Rng;

    let dataset = args.get_str("dataset", "7x6");
    let corrupt = args.get_f64("corrupt", 25.0)?;
    let engine = args.get_str("engine", "native");
    let seed = args.get_u64("seed", 1)?;
    args.finish().map_err(|e| anyhow!(e))?;

    let set = benchmark_by_name(&dataset).ok_or_else(|| anyhow!("unknown dataset"))?;
    let kind = match engine.as_str() {
        "native" => EngineKind::Native,
        "pjrt" => EngineKind::Pjrt,
        _ => return Err(anyhow!("--engine must be native|pjrt")),
    };
    let p = set.cfg.period() as i32;
    let coord = Coordinator::start(
        vec![PoolSpec::new(set.cfg, set.weights.clone(), kind)],
        BatchPolicy::default(),
    )?;
    let mut rng = Rng::new(seed);
    for target in &set.dataset.patterns {
        let flips = target.corruption_count(corrupt);
        let corrupted = target.corrupt(flips, &mut rng);
        let req = RetrievalRequest::from_pattern(coord.next_id(), &corrupted, p, 256);
        let res = coord.retrieve_sync(req)?;
        let spins = state_to_spins(&res.phases, p);
        let ok = target.matches_up_to_inversion(&spins);
        println!(
            "pattern {:<8} corrupt {flips:>3}px  settled {:>4?}  retrieved {}  ({:.2} ms)",
            target.name,
            res.settled,
            if ok { "OK " } else { "WRONG" },
            res.total_latency.as_secs_f64() * 1e3
        );
    }
    let snap = coord.snapshot();
    println!(
        "service: {} completed, mean latency {:.2} ms, occupancy {:.1}",
        snap.completed, snap.mean_total_ms, snap.mean_occupancy
    );
    coord.shutdown()
}

fn cmd_maxcut(args: &mut Args) -> Result<()> {
    use onn_scale::apps::maxcut::{solve_onn, solve_sa, Graph};
    use onn_scale::util::rng::Rng;

    let nodes = args.get_usize("nodes", 32)?;
    let prob = args.get_f64("prob", 0.3)?;
    let restarts = args.get_usize("restarts", 20)?;
    let seed = args.get_u64("seed", 7)?;
    args.finish().map_err(|e| anyhow!(e))?;

    let mut rng = Rng::new(seed);
    let g = Graph::random(nodes, prob, &mut rng);
    println!("graph: {} nodes, {} edges", g.n, g.edges.len());
    let onn = solve_onn(&g, restarts, 128, seed + 1);
    let sa = solve_sa(&g, 200, seed + 2);
    println!("ONN   cut = {:>6}   (restarts {restarts})", onn.cut);
    println!("SA    cut = {:>6}   (200 sweeps baseline)", sa.cut);
    println!("ratio ONN/SA = {:.3}", onn.cut as f64 / sa.cut.max(1) as f64);
    Ok(())
}

fn cmd_coloring(args: &mut Args) -> Result<()> {
    use onn_scale::apps::coloring::{solve_greedy, solve_onn};
    use onn_scale::apps::maxcut::Graph;
    use onn_scale::util::rng::Rng;

    let nodes = args.get_usize("nodes", 16)?;
    let colors = args.get_usize("colors", 2)?;
    let restarts = args.get_usize("restarts", 20)?;
    let seed = args.get_u64("seed", 3)?;
    args.finish().map_err(|e| anyhow!(e))?;

    if !(2..=16).contains(&colors) {
        return Err(anyhow!("--colors must be in 2..=16 (16-step phase wheel)"));
    }
    let mut rng = Rng::new(seed);
    let g = Graph::random(nodes, 0.2, &mut rng);
    println!("graph: {} nodes, {} edges, k = {colors}", g.n, g.edges.len());
    let onn = solve_onn(&g, colors, restarts, 128, seed + 1);
    let greedy = solve_greedy(&g, colors);
    println!("ONN    conflicts = {}", onn.conflicts);
    println!("greedy conflicts = {}", greedy.conflicts);
    Ok(())
}

/// Generic Ising solve: reduce the chosen problem family onto the
/// solver IR, run the annealed batched portfolio, and report quality
/// against the matching classical baseline.
fn cmd_solve(args: &mut Args) -> Result<()> {
    use onn_scale::solver::anneal::Schedule;
    use onn_scale::solver::graph::Graph;
    use onn_scale::solver::portfolio::{solve_with_trace, EngineSelect, PortfolioParams};
    use onn_scale::solver::{reductions, sa};
    use onn_scale::telemetry;
    use onn_scale::util::rng::Rng;

    let problem_kind = args.get_str("problem", "maxcut");
    let nodes = args.get_usize("nodes", 64)?;
    let prob = args.get_f64("prob", 0.1)?;
    let colors = args.get_usize("colors", 3)?;
    let replicas = args.get_usize("replicas", 32)?;
    let periods = args.get_usize("periods", 256)?;
    let schedule_name = args.get_str("schedule", "geometric");
    let noise = args.get_f64("noise", 0.6)?;
    let seed = args.get_u64("seed", 7)?;
    let shards = args.get_usize("shards", 0)?;
    let rtl = args.has("rtl");
    // 0 = unset (both bounds start at 3, so 0 is unambiguous).
    let weight_bits = args.get_usize("weight-bits", 0)?;
    let phase_bits = args.get_usize("phase-bits", 0)?;
    let trace_path = args.get_opt_str("trace");
    args.finish().map_err(|e| anyhow!(e))?;

    let schedule = Schedule::parse(&schedule_name, noise)
        .ok_or_else(|| anyhow!("--schedule must be geometric|linear|constant"))?;
    if trace_path.is_some() && problem_kind == "coloring" {
        return Err(anyhow!(
            "--trace is supported for the portfolio problems \
             (maxcut|partition|cover), not coloring"
        ));
    }
    let trace_cap = telemetry::DEFAULT_TRACE_CAP;
    let trace_sink = trace_path.as_ref().map(|_| telemetry::sink(trace_cap));
    // The precision sweep only exists on the quantized hardware model:
    // float engines have no weight/phase word widths to sweep.
    let precision: Option<(u32, u32)> = if weight_bits == 0 && phase_bits == 0 {
        None
    } else {
        if !rtl {
            return Err(anyhow!("--weight-bits/--phase-bits require --rtl"));
        }
        let wb = if weight_bits == 0 { 5 } else { weight_bits };
        let pb = if phase_bits == 0 { 4 } else { phase_bits };
        if !(3..=8).contains(&wb) {
            return Err(anyhow!("--weight-bits must be in 3..=8, got {wb}"));
        }
        if !(3..=6).contains(&pb) {
            return Err(anyhow!("--phase-bits must be in 3..=6, got {pb}"));
        }
        Some((wb as u32, pb as u32))
    };
    if precision.is_some() && problem_kind == "coloring" {
        return Err(anyhow!(
            "--weight-bits/--phase-bits are supported for the portfolio \
             problems (maxcut|partition|cover), not coloring"
        ));
    }
    // 0 = size-based auto-selection; 1 = force native; K > 1 = force a
    // K-shard cluster (bit-identical either way).  --rtl instead runs
    // the bit-true emulated-hardware engine, and --rtl --shards K>1
    // composes K of them into the emulated multi-FPGA cluster
    // (row-split weight memory, priced phase all-gathers).
    let select = if rtl {
        match shards {
            0 | 1 => EngineSelect::Rtl,
            k => EngineSelect::RtlCluster { shards: k },
        }
    } else {
        match shards {
            0 => EngineSelect::default(),
            1 => EngineSelect::Native,
            k => EngineSelect::Sharded { shards: k },
        }
    };
    let params = PortfolioParams {
        replicas,
        max_periods: periods,
        schedule,
        seed,
        precision,
        ..Default::default()
    };
    // Emulated-hardware cost line for rtl solves (silent elsewhere).
    let print_hardware = |out: &onn_scale::solver::portfolio::SolveOutcome| {
        if let Some(hw) = &out.hardware {
            println!(
                "emulated hardware: {} fast cycles ({} on cluster all-gathers) \
                 @ {:.1} MHz -> {:.3e} s (fits device: {}, quantization error {:.4})",
                hw.fast_cycles, hw.sync_fast_cycles, hw.f_logic_mhz, hw.emulated_s,
                hw.fits_device, out.quantization_error
            );
        }
    };
    let mut rng = Rng::new(seed);
    match problem_kind.as_str() {
        "maxcut" => {
            let g = Graph::random(nodes, prob, &mut rng);
            let problem = reductions::max_cut(&g);
            let out = solve_with_trace(&problem, &params, select, trace_sink.as_ref())?;
            let cut = g.cut_value(&out.best_spins);
            let sweeps = replicas * periods;
            let base = sa::anneal(&problem, sweeps, seed + 1);
            let sa_cut = g.cut_value(&base.spins);
            println!("graph: {} nodes, {} edges", g.n, g.edges.len());
            println!(
                "ONN portfolio cut = {cut:>6}   ({replicas} replicas x {periods} periods, \
                 {} settled, {} schedule, {} engine, {} sync rounds)",
                out.settled_replicas,
                schedule.name(),
                out.engine,
                out.sync_rounds
            );
            println!("SA baseline   cut = {sa_cut:>6}   ({sweeps} sweeps, equal spin updates)");
            println!("ratio ONN/SA = {:.3}", cut as f64 / sa_cut.max(1) as f64);
            print_hardware(&out);
        }
        "coloring" => {
            use onn_scale::apps::coloring::{conflicts, solve_greedy, solve_onn_with};
            if !(2..=16).contains(&colors) {
                return Err(anyhow!("--colors must be in 2..=16 (16-step phase wheel)"));
            }
            let g = Graph::random(nodes, prob, &mut rng);
            let onn = solve_onn_with(&g, colors, replicas, periods, seed + 1, select);
            let greedy = solve_greedy(&g, colors);
            println!(
                "graph: {} nodes, {} edges, k = {colors}",
                g.n,
                g.edges.len()
            );
            println!("ONN    conflicts = {}", onn.conflicts);
            println!("greedy conflicts = {}", greedy.conflicts);
            debug_assert_eq!(conflicts(&g, &onn.colors), onn.conflicts);
        }
        "partition" => {
            let weights: Vec<i64> = (0..nodes).map(|_| rng.range_i64(1, 100)).collect();
            let problem = reductions::number_partition(&weights);
            let out = solve_with_trace(&problem, &params, select, trace_sink.as_ref())?;
            let imbalance = reductions::partition_imbalance(&weights, &out.best_spins);
            let total: i64 = weights.iter().sum();
            println!("partitioning {nodes} numbers summing to {total}");
            println!(
                "ONN portfolio imbalance = {imbalance}   ({} engine, {} sync rounds)",
                out.engine, out.sync_rounds
            );
            print_hardware(&out);
        }
        "cover" => {
            let g = Graph::random(nodes, prob, &mut rng);
            let problem = reductions::min_vertex_cover(&g, 2.0);
            let out = solve_with_trace(&problem, &params, select, trace_sink.as_ref())?;
            let cover = reductions::decode_cover(&g, &out.best_spins);
            let greedy = reductions::decode_cover(&g, &vec![-1i8; g.n]);
            println!("graph: {} nodes, {} edges", g.n, g.edges.len());
            println!(
                "ONN cover size    = {} (valid: {})",
                reductions::cover_size(&cover),
                reductions::is_cover(&g, &cover)
            );
            println!(
                "greedy cover size = {}",
                reductions::cover_size(&greedy)
            );
            print_hardware(&out);
        }
        other => {
            return Err(anyhow!(
                "--problem '{other}' unknown (maxcut|coloring|partition|cover)"
            ))
        }
    }
    if let (Some(path), Some(sink)) = (&trace_path, &trace_sink) {
        let rec = sink.borrow();
        std::fs::write(path, rec.to_jsonl())
            .map_err(|e| anyhow!("cannot write trace to {path}: {e}"))?;
        println!(
            "trace: {} records ({} dropped to the ring) -> {path}",
            rec.len(),
            rec.dropped()
        );
    }
    Ok(())
}

/// Validate a JSONL trace export (`solve --trace FILE`) against the
/// telemetry schema — the `trace-check` CI gate.
fn cmd_trace_check(args: &mut Args) -> Result<()> {
    use onn_scale::telemetry::validate_trace_jsonl;

    let path = args.get_str("path", "trace.jsonl");
    args.finish().map_err(|e| anyhow!(e))?;
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow!("cannot read {path}: {e} (run solve --trace first)"))?;
    let count = validate_trace_jsonl(&text).map_err(|e| anyhow!("invalid trace {path}: {e}"))?;
    println!("trace OK: {count} records ({path})");
    Ok(())
}

/// Solver harness: head-to-head quality vs SA on G(64, 0.1), plus the
/// throughput sweep recorded to BENCH_solver.json.
fn cmd_solve_bench(args: &mut Args) -> Result<()> {
    use onn_scale::harness::solverbench;

    let sizes_str = args.get_str("sizes", "16,32,64,128");
    let replicas = args.get_usize("replicas", 32)?;
    let periods = args.get_usize("periods", 128)?;
    let instances = args.get_usize("instances", 5)?;
    let shards = args.get_usize("shards", 0)?;
    // `--packed` alone records the default 6-instance mix; `--packed N`
    // sizes the mix explicitly.
    let packed_problems = if args.has("packed") {
        args.get_usize("packed", 6)?.max(2)
    } else {
        0
    };
    let rtl = args.has("rtl");
    let rtl_packed = args.has("rtl-packed");
    let rtl_cluster = args.has("rtl-cluster");
    // `--connections` alone records the 64-client row of the issue's
    // acceptance gate; `--connections N` sizes it explicitly.
    let connections = if args.has("connections") {
        args.get_usize("connections", 64)?.max(1)
    } else {
        0
    };
    let sparse = args.has("sparse");
    let associative = args.has("associative");
    let out_path = args.get_str("out", "BENCH_solver.json");
    let seed = args.get_u64("seed", 2025)?;
    args.finish().map_err(|e| anyhow!(e))?;

    let sizes: Vec<usize> = sizes_str
        .split(',')
        .map(|s| s.trim().parse().map_err(|_| anyhow!("bad --sizes entry '{s}'")))
        .collect::<Result<_>>()?;

    let report = solverbench::quality_vs_sa(64, 0.1, instances, replicas, periods, seed);
    println!("{}", report.table());

    let bench = solverbench::record_throughput(
        std::path::Path::new(&out_path),
        &sizes,
        replicas,
        periods,
        seed,
        shards,
        packed_problems,
        rtl,
        rtl_packed,
        rtl_cluster,
        connections,
        sparse,
        associative,
    )?;
    println!("solver throughput (native vs sharded replica-periods/sec):");
    for p in &bench.points {
        println!(
            "  n={:<5} {:>9} {:>12.0} replica-periods/s   (median {:.3} s per \
             solve, {} sync rounds)",
            p.n, p.engine, p.replica_periods_per_sec, p.median_s, p.sync_rounds
        );
    }
    for p in &bench.packed {
        println!(
            "packed serving ({} problems sharing one {}-lane engine, bucket n={}):",
            p.problems, p.lanes, p.bucket_n
        );
        println!(
            "  packed   {:>12.0} replica-periods/s   (median {:.3} s per mix)",
            p.packed_rps, p.packed_median_s
        );
        println!(
            "  unpacked {:>12.0} replica-periods/s   (median {:.3} s per mix)",
            p.unpacked_rps, p.unpacked_median_s
        );
    }
    if !bench.rtl.is_empty() {
        println!("float-native vs bit-true rtl (quality + emulated time-to-solution):");
        for p in &bench.rtl {
            println!(
                "  n={:<5} cut {:>5} vs {:>5} (native/rtl)  quant err {:.4}  \
                 {} fast cycles @ {:.1} MHz -> {:.3e} s emulated ({:.3} s host sim)",
                p.n,
                p.native_cut,
                p.rtl_cut,
                p.quantization_error,
                p.fast_cycles,
                p.f_logic_mhz,
                p.emulated_s,
                p.host_s
            );
        }
    }
    for p in &bench.rtl_packed {
        println!(
            "rtl lane-bank packing ({} problems sharing one {}-lane emulated \
             fabric, bucket n={}):",
            p.problems, p.lanes, p.bucket_n
        );
        println!(
            "  packed {} fast cycles -> {:>10.0} emulated solves/s \
             (host median {:.3} s)",
            p.packed_fast_cycles, p.packed_emulated_solves_per_sec, p.packed_host_median_s
        );
        println!(
            "  solo   {} fast cycles -> {:>10.0} emulated solves/s \
             (host median {:.3} s)",
            p.solo_fast_cycles, p.solo_emulated_solves_per_sec, p.solo_host_median_s
        );
    }
    for p in &bench.rtl_cluster {
        println!(
            "emulated {}-FPGA cluster: n={} (single-device fit {}), {} compute \
             + {} sync fast cycles @ {:.1} MHz -> {:.3e} s emulated \
             ({:.3} s host sim, fits per shard: {})",
            p.shards,
            p.n,
            p.single_device_fit,
            p.compute_fast_cycles,
            p.sync_fast_cycles,
            p.f_logic_mhz,
            p.emulated_s,
            p.host_s,
            p.fits_device
        );
    }
    println!("solve latency percentiles (log-bucketed, upper-bound estimates):");
    for p in &bench.latency {
        println!(
            "  {:<8} n={:<4} {} samples  mean {:.3} ms  p50 {:.3}  p90 {:.3}  \
             p99 {:.3} ms",
            p.engine,
            p.n,
            p.samples,
            p.summary.mean_ms,
            p.summary.p50_ms,
            p.summary.p90_ms,
            p.summary.p99_ms
        );
    }
    if !bench.connection_scale.is_empty() {
        println!("connection scale (sustained solves/sec, streaming clients):");
        for p in &bench.connection_scale {
            println!(
                "  {:>4} clients  baseline {:>8.1}/s ({} solves)  evented \
                 {:>8.1}/s ({} solves)  speedup {:.2}x  arena hit rate {:.2}",
                p.clients,
                p.baseline_solves_per_sec,
                p.baseline_solves,
                p.evented_solves_per_sec,
                p.evented_solves,
                p.speedup,
                p.arena_hit_rate
            );
        }
    }
    if !bench.sparse.is_empty() {
        println!("dense vs CSR fabric (bit-exact work, replica-periods/sec):");
        for p in &bench.sparse {
            println!(
                "  n={:<5} density {:.3} ({:.1} nnz/row)  dense {:>10.0}/s  \
                 csr {:>10.0}/s  speedup {:.2}x  weights {} -> {} bytes  \
                 hw {:.2} -> {:.2} kHz",
                p.n,
                p.density,
                p.avg_row_nnz,
                p.dense_replica_periods_per_sec,
                p.sparse_replica_periods_per_sec,
                p.sparse_speedup,
                p.dense_weight_bytes,
                p.sparse_weight_bytes,
                p.hw_dense_khz,
                p.hw_sparse_khz
            );
        }
    }
    for p in &bench.associative {
        println!(
            "associative memory (n={}, capacity {}, {} recalls on the {} \
             engine, {} shards):",
            p.n, p.capacity, p.recalls, p.engine, p.shards
        );
        println!(
            "  delta-reprogram {:>9.1} recalls/s (median {:.4} s)",
            p.delta_recalls_per_sec, p.delta_median_s
        );
        println!(
            "  full rebuild    {:>9.1} recalls/s (median {:.4} s)   \
             speedup {:.2}x",
            p.rebuild_recalls_per_sec, p.rebuild_median_s, p.speedup
        );
        for l in &p.load {
            println!(
                "    stored {:>3} after {:>3} stores: recall accuracy \
                 {:>5.2} ({}/{} corrupted probes)",
                l.patterns, l.stores, l.accuracy, l.matched, l.trials
            );
        }
    }
    println!("convergence traces (running best energy per anneal chunk):");
    for c in &bench.convergence {
        let first = c.best_energy.first().copied().unwrap_or(0.0);
        println!(
            "  n={:<5} {:>8} {} waves, {} chunks: {:.2} -> {:.2} (final {:.2}, \
             monotone: {})",
            c.n,
            c.engine,
            c.waves,
            c.best_energy.len(),
            first,
            c.best_energy.last().copied().unwrap_or(first),
            c.final_energy,
            if c.monotone { "yes" } else { "NO" }
        );
    }
    Ok(())
}

/// Render the recorded `BENCH_solver.json` trajectory next to the paper
/// tables (the harness/report wiring of the solver-path benchmarks).
fn cmd_solve_report(args: &mut Args) -> Result<()> {
    use onn_scale::util::json::Json;

    let path = args.get_str("path", "BENCH_solver.json");
    args.finish().map_err(|e| anyhow!(e))?;
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow!("cannot read {path}: {e} (run solve-bench first)"))?;
    let doc = Json::parse(&text).map_err(|e| anyhow!("bad JSON in {path}: {e}"))?;
    println!("{}", report::solver_bench_report(&doc));
    Ok(())
}

fn cmd_serve(args: &mut Args) -> Result<()> {
    let addr = args.get_str("addr", "127.0.0.1:7020");
    let dataset = args.get_str("dataset", "7x6");
    let engine = args.get_str("engine", "native");
    // The evented readiness loop is the default front end (streaming
    // progress + disconnect cancellation, DESIGN_SOLVER.md §10);
    // `--threads` keeps the thread-per-connection baseline.
    let threads = args.has("threads");
    args.finish().map_err(|e| anyhow!(e))?;

    let set = benchmark_by_name(&dataset).ok_or_else(|| anyhow!("unknown dataset"))?;
    let kind = match engine.as_str() {
        "native" => EngineKind::Native,
        "pjrt" => EngineKind::Pjrt,
        _ => return Err(anyhow!("--engine must be native|pjrt")),
    };
    let coord = Coordinator::start(
        vec![PoolSpec::new(set.cfg, set.weights.clone(), kind)],
        BatchPolicy {
            max_wait: Duration::from_millis(2),
            max_periods_cap: 512,
        },
    )?;
    let listener = std::net::TcpListener::bind(&addr)?;
    println!(
        "serving dataset {} (n={}) on {} via {} engine ({} front end); \
         JSON-lines: {{\"n\":{},\"phases\":[...]}}",
        dataset,
        set.cfg.n,
        addr,
        engine,
        if threads { "thread-per-connection" } else { "evented" },
        set.cfg.n
    );
    if threads {
        serve_tcp(Arc::clone(&coord.router), listener)
    } else {
        serve_evented(Arc::clone(&coord.router), listener)
    }
}

/// Live store -> recall -> forget -> recall smoke through the evented
/// front end on an ephemeral port, asserting every wire reply plus the
/// metrics counters (the `assoc-smoke` gate run by `scripts/ci.sh`).
fn cmd_assoc_smoke(args: &mut Args) -> Result<()> {
    use std::io::{BufRead, BufReader, Write};

    use onn_scale::coordinator::server::SolverPoolConfig;

    let periods = args.get_usize("periods", 64)?;
    args.finish().map_err(|e| anyhow!(e))?;

    let coord = Coordinator::start_with_solver(
        Vec::new(),
        BatchPolicy::default(),
        SolverPoolConfig::default(),
    )?;
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let router = Arc::clone(&coord.router);
    let serve = std::thread::spawn(move || serve_evented(router, listener));

    // The paper's 3x3 glyph pair.  Under the DO-I rule the stored
    // glyphs are fixed points of the quantized matrix (pinned by the
    // learning tests), so recalling an exact stored probe must settle
    // and match deterministically.
    let ds = onn_scale::onn::patterns::dataset_3x3();
    let spin_json = |spins: &[i8]| {
        let cells: Vec<String> = spins.iter().map(|s| s.to_string()).collect();
        format!("[{}]", cells.join(","))
    };
    let a = spin_json(&ds.patterns[0].spins);
    let b = spin_json(&ds.patterns[1].spins);

    let stream = std::net::TcpStream::connect(addr)?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    let mut roundtrip = |req: String| -> Result<String> {
        writer.write_all(req.as_bytes())?;
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Err(anyhow!("server closed the connection"));
        }
        Ok(line.trim_end().to_string())
    };
    let expect = |step: &str, reply: &str, needles: &[&str]| -> Result<()> {
        for needle in needles {
            if !reply.contains(needle) {
                return Err(anyhow!("{step}: expected {needle} in reply {reply}"));
            }
        }
        Ok(())
    };

    let r = roundtrip(format!(
        "{{\"type\":\"store\",\"id\":1,\"space\":\"smoke\",\"spins\":{a},\
         \"rule\":\"doi\"}}\n"
    ))?;
    expect(
        "store A",
        &r,
        &["\"type\":\"stored\"", "\"patterns\":1", "\"duplicate\":false"],
    )?;
    println!("  store A   -> {r}");
    let r = roundtrip(format!(
        "{{\"type\":\"store\",\"id\":2,\"space\":\"smoke\",\"spins\":{b},\
         \"rule\":\"doi\"}}\n"
    ))?;
    expect("store B", &r, &["\"type\":\"stored\"", "\"patterns\":2"])?;
    println!("  store B   -> {r}");
    let r = roundtrip(format!(
        "{{\"type\":\"recall\",\"id\":3,\"space\":\"smoke\",\"spins\":{a},\
         \"max_periods\":{periods}}}\n"
    ))?;
    expect("recall A", &r, &["\"type\":\"recall\"", "\"matched\":true"])?;
    println!("  recall A  -> {r}");
    let r = roundtrip(format!(
        "{{\"type\":\"forget\",\"id\":4,\"space\":\"smoke\",\"spins\":{a}}}\n"
    ))?;
    expect("forget A", &r, &["\"type\":\"forgotten\"", "\"patterns\":1"])?;
    println!("  forget A  -> {r}");
    let r = roundtrip(format!(
        "{{\"type\":\"recall\",\"id\":5,\"space\":\"smoke\",\"spins\":{b},\
         \"max_periods\":{periods}}}\n"
    ))?;
    expect("recall B", &r, &["\"type\":\"recall\"", "\"matched\":true"])?;
    println!("  recall B  -> {r}");
    let r = roundtrip("{\"type\":\"metrics\"}\n".to_string())?;
    expect(
        "metrics",
        &r,
        &[
            "\"patterns_stored\":2",
            "\"patterns_forgotten\":1",
            "\"recalls\":2",
            "\"recalls_matched\":2",
        ],
    )?;
    println!("  metrics   -> stored 2, forgotten 1, recalls 2/2 matched");

    coord.shutdown()?;
    serve
        .join()
        .map_err(|_| anyhow!("serve thread panicked"))??;
    println!(
        "assoc smoke OK: store x2 -> recall (matched) -> forget -> recall \
         (matched) -> metrics over one evented connection"
    );
    Ok(())
}

/// Cross-validate the PJRT artifact against the bit-exact native engine.
#[cfg(not(feature = "pjrt"))]
fn cmd_crosscheck(args: &mut Args) -> Result<()> {
    args.finish().map_err(|e| anyhow!(e))?;
    Err(anyhow!(
        "crosscheck needs the PJRT engine; rebuild with --features pjrt \
         (and point the vendored xla dependency at the real crate)"
    ))
}

/// Cross-validate the PJRT artifact against the bit-exact native engine.
#[cfg(feature = "pjrt")]
fn cmd_crosscheck(args: &mut Args) -> Result<()> {
    use onn_scale::runtime::artifact::{default_dir, Manifest};
    use onn_scale::runtime::engine::{PjrtContext, PjrtEngine};
    use onn_scale::runtime::native::NativeEngine;
    use onn_scale::runtime::ChunkEngine;
    use onn_scale::util::rng::Rng;

    let dataset = args.get_str("dataset", "3x3");
    let trials = args.get_usize("trials", 16)?;
    let seed = args.get_u64("seed", 5)?;
    args.finish().map_err(|e| anyhow!(e))?;

    let set = benchmark_by_name(&dataset).ok_or_else(|| anyhow!("unknown dataset"))?;
    let manifest = Manifest::load(&default_dir())?;
    let info = manifest
        .chunk_for(set.cfg.n)
        .ok_or_else(|| anyhow!("no artifact for n={}", set.cfg.n))?;
    let ctx = PjrtContext::cpu()?;
    let mut pjrt = PjrtEngine::load(ctx, info)?;
    let mut native = NativeEngine::new(set.cfg, info.batch, info.chunk);
    let w = set.weights.to_f32();
    pjrt.set_weights(&w)?;
    native.set_weights(&w)?;

    let mut rng = Rng::new(seed);
    let b = info.batch;
    let n = set.cfg.n;
    let mut mismatches = 0usize;
    for round in 0..trials.div_ceil(b) {
        let init: Vec<i32> = (0..b * n).map(|_| rng.range_i64(0, 16) as i32).collect();
        let (mut ph_a, mut ph_b) = (init.clone(), init);
        let (mut st_a, mut st_b) = (vec![-1i32; b], vec![-1i32; b]);
        for chunk_idx in 0..4 {
            let p0 = (chunk_idx * info.chunk) as i32;
            pjrt.run_chunk(&mut ph_a, &mut st_a, p0)?;
            native.run_chunk(&mut ph_b, &mut st_b, p0)?;
        }
        if ph_a != ph_b || st_a != st_b {
            mismatches += 1;
            eprintln!("round {round}: MISMATCH");
        }
    }
    if mismatches == 0 {
        println!(
            "crosscheck OK: pjrt == native bit-exact over {} rounds (n={}, batch={})",
            trials.div_ceil(b),
            n,
            b
        );
        Ok(())
    } else {
        Err(anyhow!("{mismatches} mismatched rounds"))
    }
}

fn cmd_ablation(args: &mut Args) -> Result<()> {
    use onn_scale::harness::ablation::{precision_sweep, precision_table};
    let trials = args.get_usize("trials", 50)?;
    let seed = args.get_u64("seed", 1)?;
    args.finish().map_err(|e| anyhow!(e))?;
    println!("{}", precision_table(&precision_sweep(trials, seed)));
    Ok(())
}

fn cmd_capacity(args: &mut Args) -> Result<()> {
    use onn_scale::harness::ablation::{capacity_sweep, capacity_table};
    let n = args.get_usize("n", 20)?;
    let trials = args.get_usize("trials", 50)?;
    let seed = args.get_u64("seed", 1)?;
    args.finish().map_err(|e| anyhow!(e))?;
    println!("{}", capacity_table(n, &capacity_sweep(n, trials, seed)));
    Ok(())
}

/// Multi-device sharding demo: split one logical network across K shard
/// workers and verify against the single-engine result (the paper's
/// future-work multi-FPGA topology).
fn cmd_shard_demo(args: &mut Args) -> Result<()> {
    use onn_scale::onn::dynamics::FunctionalEngine;
    use onn_scale::runtime::sharded::ShardedEngine;
    use onn_scale::runtime::ChunkEngine;
    use onn_scale::util::rng::Rng;

    let n_flag = args.get_usize("n", 42)?;
    let shards = args.get_usize("shards", 4)?;
    let seed = args.get_u64("seed", 1)?;
    args.finish().map_err(|e| anyhow!(e))?;

    let set = match n_flag {
        9 => benchmark_by_name("3x3"),
        20 => benchmark_by_name("5x4"),
        42 => benchmark_by_name("7x6"),
        100 => benchmark_by_name("10x10"),
        484 => benchmark_by_name("22x22"),
        _ => return Err(anyhow!("--n must be one of 9, 20, 42, 100, 484")),
    }
    .unwrap();
    let mut rng = Rng::new(seed);
    let b = 4usize;
    let n = set.cfg.n;
    let init: Vec<i32> = (0..b * n).map(|_| rng.range_i64(0, 16) as i32).collect();

    let mut single = FunctionalEngine::new(set.cfg, set.weights.clone());
    let mut sh = ShardedEngine::new(set.cfg, &set.weights, shards, b, 16)?;
    let (mut pa, mut pb) = (init.clone(), init);
    let (mut sa, mut sb) = (vec![-1i32; b], vec![-1i32; b]);
    let t0 = std::time::Instant::now();
    single.run_chunk(&mut pa, &mut sa, 0, 16);
    let t_single = t0.elapsed();
    let t1 = std::time::Instant::now();
    sh.run_chunk(&mut pb, &mut sb, 0)?;
    let t_shard = t1.elapsed();
    println!(
        "n={n}, {shards} shards, {b} trials x 16 periods: single {:.2} ms, sharded {:.2} ms",
        t_single.as_secs_f64() * 1e3,
        t_shard.as_secs_f64() * 1e3
    );
    println!(
        "bit-exact: {}   all-gather sync rounds: {}",
        pa == pb && sa == sb,
        sh.sync_rounds
    );
    if pa != pb {
        return Err(anyhow!("sharded result diverged"));
    }
    sh.shutdown();
    Ok(())
}

fn cmd_info() -> Result<()> {
    use onn_scale::runtime::artifact::{default_dir, Manifest};

    let dir = default_dir();
    println!("artifact dir: {}", dir.display());
    match Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts ({}):", m.artifacts.len());
            for a in &m.artifacts {
                println!(
                    "  {:<40} n={:<4} batch={:<3} chunk={:<3} kind={}",
                    a.file.file_name().unwrap_or_default().to_string_lossy(),
                    a.n,
                    a.batch,
                    a.chunk,
                    a.kind
                );
            }
        }
        Err(e) => println!("no manifest: {e:#}"),
    }
    #[cfg(feature = "pjrt")]
    {
        use onn_scale::runtime::engine::PjrtContext;
        match PjrtContext::cpu() {
            Ok(ctx) => println!("pjrt platform: {}", ctx.platform()),
            Err(e) => println!("pjrt unavailable: {e:#}"),
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("pjrt: disabled at build time (rebuild with --features pjrt)");
    Ok(())
}
