//! # onn-scale
//!
//! Reproduction of *"Overcoming Quadratic Hardware Scaling for a Fully
//! Connected Digital Oscillatory Neural Network"* (Haverkort &
//! Todri-Sanial, CS.AR 2025) as a three-layer Rust + JAX + Pallas stack.
//!
//! The crate contains every substrate the paper depends on:
//!
//! * [`onn`] — the domain core: quantized phases/weights, the
//!   Diederich-Opper-I learning rule, letter-pattern datasets, and the
//!   functional (period-level) dynamics engine that bit-exactly mirrors
//!   the AOT-compiled JAX model.
//! * [`rtl`] — cycle-accurate simulators of the paper's two digital
//!   architectures: the prior-art *recurrent* design (parallel adder
//!   trees, quadratic hardware) and the proposed *hybrid* design (serial
//!   MAC per oscillator, near-linear hardware).
//! * [`fpga`] — the Zynq-7020 resource/timing model and the log-log
//!   regression used for the paper's scaling figures.
//! * [`runtime`] — the engines behind one batched chunk contract: the
//!   PJRT executor for the HLO-text artifacts of
//!   `python/compile/aot.py`, the bit-exact native fallback, the
//!   row-sharded multi-device cluster, and the bit-true
//!   emulated-hardware engine over the RTL hybrid datapath.
//! * [`coordinator`] — the retrieval service: request router, dynamic
//!   batcher and worker pool feeding the engines.
//! * [`harness`] — drivers that regenerate every table and figure of the
//!   paper's evaluation section, and the micro-benchmark timer used by
//!   `cargo bench` (criterion is unavailable offline).
//! * [`solver`] — the generic Ising/QUBO optimization subsystem: a
//!   problem IR with reductions (max-cut, k-coloring, number
//!   partitioning, vertex cover), phase-noise annealing schedules, and
//!   the batched replica-portfolio driver served by the coordinator.
//! * [`apps`] — the paper's future-work applications: max-cut and graph
//!   coloring as thin reductions/decoders over [`solver`].
//! * [`telemetry`] — observability: the solve-lifecycle trace recorder
//!   threaded through the portfolio and the engines, and the
//!   log-bucketed latency histograms behind the coordinator's metrics
//!   percentiles and `"type": "metrics"` wire command.
//! * [`util`] — in-tree infrastructure (deterministic RNG, minimal JSON,
//!   stats, CLI parsing) standing in for crates that are not available
//!   in this offline image.
//!
//! See `DESIGN.md` for the full system inventory and the experiment
//! index, and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod apps;
pub mod coordinator;
pub mod fpga;
pub mod harness;
pub mod onn;
pub mod rtl;
pub mod runtime;
pub mod solver;
pub mod telemetry;
pub mod util;

pub use onn::config::NetworkConfig;
pub use onn::dynamics::FunctionalEngine;
pub use onn::patterns::{Dataset, Pattern};
pub use onn::weights::WeightMatrix;
