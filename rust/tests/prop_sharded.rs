//! Property tests for the sharded solve fabric: a leader + K row-shard
//! workers must be bit-exact with the single `NativeEngine` — with the
//! annealing phase noise enabled — at every period, for random sizes,
//! weights, seeds, and shard counts (K = 1..5, including splits that do
//! not divide the row count).  This is the faithfulness question the
//! multi-device discussion of the paper raises: distributing the rows
//! (and the kick stream) must not change the dynamics at all.

use onn_scale::onn::config::NetworkConfig;
use onn_scale::runtime::native::NativeEngine;
use onn_scale::runtime::sharded::ShardedEngine;
use onn_scale::runtime::ChunkEngine;
use onn_scale::solver::portfolio::{solve_native, solve_with, EngineSelect, PortfolioParams};
use onn_scale::solver::reductions::max_cut;
use onn_scale::solver::Graph;
use onn_scale::util::rng::Rng;

fn rand_weights_f32(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n * n).map(|_| rng.range_i64(-16, 16) as f32).collect()
}

#[test]
fn prop_sharded_noisy_dynamics_bit_exact_at_every_period() {
    let mut rng = Rng::new(9001);
    for case in 0..25 {
        let n = 2 + rng.usize_below(22); // 2..=23: plenty of non-dividing splits
        for k in 1..=5usize {
            let shards = k.min(n);
            let cfg = NetworkConfig::paper(n);
            let batch = 1 + rng.usize_below(3);
            // chunk = 1 makes every run_chunk a single period, so the
            // walk below compares the trajectories period by period.
            let mut native = NativeEngine::new(cfg, batch, 1);
            let mut sharded = ShardedEngine::unprogrammed(cfg, shards, batch, 1).unwrap();
            let w = rand_weights_f32(&mut rng, n);
            native.set_weights(&w).unwrap();
            sharded.set_weights(&w).unwrap();
            let amplitude = 0.2 + rng.f64() * 0.8;
            let seed = rng.next_u64();
            native.set_noise(amplitude, seed).unwrap();
            sharded.set_noise(amplitude, seed).unwrap();
            let init: Vec<i32> = (0..batch * n).map(|_| rng.range_i64(0, 16) as i32).collect();
            let (mut pa, mut pb) = (init.clone(), init);
            let (mut sa, mut sb) = (vec![-1i32; batch], vec![-1i32; batch]);
            for period in 0..10 {
                native.run_chunk(&mut pa, &mut sa, period).unwrap();
                sharded.run_chunk(&mut pb, &mut sb, period).unwrap();
                assert_eq!(
                    pa, pb,
                    "case {case} n={n} shards={shards} period {period}: phases diverged"
                );
                assert_eq!(
                    sa, sb,
                    "case {case} n={n} shards={shards} period {period}: settle flags diverged"
                );
            }
            // One all-gather per period per trial: the sync-cost metric
            // is exactly the period count.
            assert_eq!(sharded.sync_rounds, (10 * batch) as u64, "case {case}");
        }
    }
}

#[test]
fn prop_sharded_tracks_mid_run_noise_changes() {
    // The portfolio re-seeds the noise before every chunk (annealing
    // schedules decay the amplitude), so equivalence must survive
    // set_noise calls interleaved with run_chunk — including turning
    // the noise off (the deterministic relaxation tail).
    let mut rng = Rng::new(9002);
    for case in 0..12 {
        let n = 3 + rng.usize_below(15);
        let shards = (2 + rng.usize_below(4)).min(n);
        let cfg = NetworkConfig::paper(n);
        let mut native = NativeEngine::new(cfg, 2, 4);
        let mut sharded = ShardedEngine::unprogrammed(cfg, shards, 2, 4).unwrap();
        let w = rand_weights_f32(&mut rng, n);
        native.set_weights(&w).unwrap();
        sharded.set_weights(&w).unwrap();
        let init: Vec<i32> = (0..2 * n).map(|_| rng.range_i64(0, 16) as i32).collect();
        let (mut pa, mut pb) = (init.clone(), init);
        let (mut sa, mut sb) = (vec![-1i32; 2], vec![-1i32; 2]);
        let levels = [0.9, 0.5, 0.2, 0.0];
        for (chunk, &level) in levels.iter().enumerate() {
            let seed = rng.next_u64();
            native.set_noise(level, seed).unwrap();
            sharded.set_noise(level, seed).unwrap();
            native.run_chunk(&mut pa, &mut sa, (chunk * 4) as i32).unwrap();
            sharded.run_chunk(&mut pb, &mut sb, (chunk * 4) as i32).unwrap();
            assert_eq!(pa, pb, "case {case} chunk {chunk} level {level}");
            assert_eq!(sa, sb, "case {case} chunk {chunk} level {level}");
        }
    }
}

#[test]
fn prop_sharded_portfolio_solve_matches_native_exactly() {
    // End to end through the annealed replica portfolio: same seed,
    // identical trajectories, identical final energies — for K = 2..5
    // on sizes where K never divides, sometimes divides, the row count.
    let mut rng = Rng::new(9003);
    for case in 0..5u64 {
        let n = 8 + rng.usize_below(10); // 8..=17
        let g = Graph::random(n, 0.3, &mut rng);
        let problem = max_cut(&g);
        let params = PortfolioParams {
            replicas: 6,
            max_periods: 48,
            seed: 4000 + case,
            ..Default::default()
        };
        let native = solve_native(&problem, &params).unwrap();
        assert_eq!(native.engine, "native");
        assert!(native.noise_applied, "native engine must anneal");
        for shards in [2usize, 3, 5] {
            let out = solve_with(&problem, &params, EngineSelect::Sharded { shards }).unwrap();
            assert_eq!(out.engine, "sharded", "case {case} shards={shards}");
            assert!(out.noise_applied, "case {case} shards={shards}");
            assert_eq!(
                out.best_energy,
                native.best_energy,
                "case {case} shards={shards}: final energies differ"
            );
            assert_eq!(out.best_phases, native.best_phases, "case {case} shards={shards}");
            assert_eq!(out.best_spins, native.best_spins, "case {case} shards={shards}");
            assert_eq!(out.periods, native.periods, "case {case} shards={shards}");
            assert_eq!(out.settled_replicas, native.settled_replicas);
            assert!(out.sync_rounds > 0, "case {case} shards={shards}");
        }
    }
}

#[test]
fn prop_auto_selection_is_transparent_to_results() {
    // Auto must route by size without changing the answer: below the
    // threshold it is the native engine; above, the sharded cluster
    // with the same bit-exact trajectory.
    let mut rng = Rng::new(9004);
    let g = Graph::random(20, 0.25, &mut rng);
    let problem = max_cut(&g);
    let params = PortfolioParams {
        replicas: 4,
        max_periods: 32,
        seed: 11,
        ..Default::default()
    };
    let native = solve_native(&problem, &params).unwrap();
    let below = solve_with(
        &problem,
        &params,
        EngineSelect::Auto { threshold: 64, max_shards: 4 },
    )
    .unwrap();
    assert_eq!(below.engine, "native");
    let above = solve_with(
        &problem,
        &params,
        EngineSelect::Auto { threshold: 8, max_shards: 3 },
    )
    .unwrap();
    assert_eq!(above.engine, "sharded");
    assert!(above.sync_rounds > 0);
    for out in [&below, &above] {
        assert_eq!(out.best_energy, native.best_energy);
        assert_eq!(out.best_phases, native.best_phases);
        assert_eq!(out.periods, native.periods);
    }
}
