//! Regression pins on the paper's headline hardware-scaling claims
//! (`harness::scaling` over the fpga resource/timing models), so a
//! refactor of the resource model, the sweep sizes, or the regression
//! fit cannot silently break the reproduction:
//!
//! * Hybrid LUT usage scales **near-linearly** — the paper's headline
//!   exponent is 1.22 (Fig. 9), "overcoming quadratic hardware
//!   scaling".
//! * Recurrent LUT usage scales **~quadratically** (paper: 2.08) — the
//!   prior-art baseline the hybrid design is measured against.
//! * The capacity consequence: ~10x more oscillators on the same
//!   device (506 vs 48, Table 5).

use onn_scale::harness::scaling::{hybrid_sweep, recurrent_sweep, table5_rows};

#[test]
fn hybrid_lut_exponent_stays_near_linear() {
    let fit = hybrid_sweep().lut_fit();
    assert!(
        (fit.slope - 1.22).abs() <= 0.15,
        "hybrid LUT exponent drifted off the paper's 1.22: {:.3}",
        fit.slope
    );
    assert!(fit.r2 > 0.97, "hybrid LUT fit degraded: r2 = {:.4}", fit.r2);
}

#[test]
fn recurrent_lut_exponent_stays_quadratic() {
    let fit = recurrent_sweep().lut_fit();
    assert!(
        (fit.slope - 2.08).abs() <= 0.25,
        "recurrent LUT exponent drifted off the paper's 2.08: {:.3}",
        fit.slope
    );
    assert!(
        fit.r2 > 0.97,
        "recurrent LUT fit degraded: r2 = {:.4}",
        fit.r2
    );
}

#[test]
fn scaling_gap_preserves_the_capacity_headline() {
    // The two exponents must stay far enough apart to reproduce the
    // paper's capacity result: ~10.5x more oscillators on the hybrid
    // design at the same device.
    let ha = hybrid_sweep().lut_fit().slope;
    let ra = recurrent_sweep().lut_fit().slope;
    assert!(
        ra - ha >= 0.6,
        "exponent gap collapsed: recurrent {ra:.3} vs hybrid {ha:.3}"
    );
    let rows = table5_rows();
    let hybrid_n = rows.iter().find(|r| r.arch == "Hybrid").unwrap().max_n;
    let recurrent_n = rows.iter().find(|r| r.arch == "Recurrent").unwrap().max_n;
    let ratio = hybrid_n as f64 / recurrent_n as f64;
    assert!(
        (9.0..=11.5).contains(&ratio),
        "capacity ratio {ratio:.2} drifted off the paper's 10.5 \
         ({hybrid_n} vs {recurrent_n})"
    );
}
