//! Property tests for packing and sharding the *emulated hardware*.
//! Lane-bank packing: an [`RtlEngine`] whose batch lanes carry
//! different Ising problems (per-block quantized weight banks,
//! block-local counter-indexed kick streams) must be **bit-exact, lane
//! by lane, with each problem solved solo** on a dedicated `--rtl`
//! engine at the same seed — including backfilled lanes, whose blocks
//! must restart the kick stream rather than resume the retired
//! problem's tick counter.  End-to-end mixes keep every embedding at
//! exactly the bucket size: outcome identity includes the settle
//! flags, and the rtl settle judge reads *relative* phases over the
//! whole lane, so a zero-padded (frozen) oscillator is part of the
//! judgment — the padding invariant itself (real oscillators'
//! trajectories untouched by zero-coupled padding) is pinned
//! separately at the chunk-walk level, where it is exact by
//! construction.  Cluster sharding: an [`RtlClusterEngine`] row-splits
//! the quantized weight memory across `K` emulated devices, which is a
//! hardware-*model* statement only — every chunk's phases and settle
//! flags must equal the single-device engine bit for bit
//! (non-dividing row splits included), and only the priced phase
//! all-gathers may differ in the reported hardware cost.

use onn_scale::fpga::timing::cluster_sync_cycles;
use onn_scale::onn::config::NetworkConfig;
use onn_scale::runtime::cluster::RtlClusterEngine;
use onn_scale::runtime::rtl::RtlEngine;
use onn_scale::runtime::ChunkEngine;
use onn_scale::solver::portfolio::{
    solve_packed, solve_with, EngineSelect, PortfolioParams, SolveOutcome,
};
use onn_scale::solver::problem::IsingProblem;
use onn_scale::solver::reductions::{coloring, max_cut, min_vertex_cover};
use onn_scale::solver::Graph;
use onn_scale::util::rng::Rng;

/// A random instance embedding into exactly `bucket` oscillators:
/// max-cut (binary), 3-coloring (sectors), or vertex cover (whose
/// field -> ancilla embedding adds one oscillator, so its graph is one
/// vertex smaller).  Replica counts, budgets, and seeds randomized.
fn random_entry_at(rng: &mut Rng, chunk: usize, bucket: usize) -> (IsingProblem, PortfolioParams) {
    let problem = match rng.usize_below(3) {
        0 => max_cut(&Graph::random(bucket, 0.35, rng)),
        1 => coloring(&Graph::random(bucket, 0.35, rng), 3),
        // Penalty 3.0 keeps the ancilla field nonzero at every vertex
        // degree (h_i = 1/2 - 3*deg_i/4 has no integer root), so the
        // field->ancilla embedding always lands exactly on `bucket`.
        _ => min_vertex_cover(&Graph::random(bucket - 1, 0.35, rng), 3.0),
    };
    assert_eq!(problem.embed_dim(), bucket, "entry must fill the bucket exactly");
    let params = PortfolioParams {
        replicas: 2 + rng.usize_below(3),              // 2..=4
        max_periods: chunk * (4 + rng.usize_below(4)), // 4..=7 chunks
        seed: rng.next_u64(),
        chunk,
        ..Default::default()
    };
    (problem, params)
}

fn assert_bit_exact(case: &str, out: &SolveOutcome, solo: &SolveOutcome) {
    assert_eq!(out.best_energy, solo.best_energy, "{case}: energies differ");
    assert_eq!(out.best_spins, solo.best_spins, "{case}: spins differ");
    assert_eq!(out.best_phases, solo.best_phases, "{case}: phases differ");
    assert_eq!(out.periods, solo.periods, "{case}: period counts differ");
    assert_eq!(out.chunks, solo.chunks, "{case}: chunk counts differ");
    assert_eq!(
        out.settled_replicas, solo.settled_replicas,
        "{case}: settle counts differ"
    );
    assert_eq!(out.early_exit, solo.early_exit, "{case}: exit kinds differ");
    assert_eq!(
        out.replica_phases, solo.replica_phases,
        "{case}: replica readouts differ"
    );
    assert_eq!(
        out.initial_best_energy, solo.initial_best_energy,
        "{case}: initial bests differ"
    );
}

/// Integer weights in the paper's quantized range, like the bit-true
/// weight memory holds.
fn rand_w(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n * n).map(|_| rng.range_i64(-8, 9) as f32).collect()
}

#[test]
fn prop_rtl_packed_mixes_bit_exact_with_solo() {
    // Random mixes of 2..=4 problems, all lanes resident at once on a
    // shared bucket-sized rtl engine — every problem must match its
    // dedicated-engine `--rtl` run bit for bit, and carry its own
    // emulated hardware share.
    let mut rng = Rng::new(8101);
    for case in 0..3 {
        for (chunk, bucket) in [(8usize, 8usize), (4, 8), (8, 16)] {
            let count = 2 + rng.usize_below(3); // 2..=4 problems
            let entries: Vec<_> =
                (0..count).map(|_| random_entry_at(&mut rng, chunk, bucket)).collect();
            let lanes: usize = entries.iter().map(|(_, p)| p.replicas).sum();
            let mut engine = RtlEngine::new(NetworkConfig::paper(bucket), lanes, chunk);
            let packed = solve_packed(&mut engine, &entries).unwrap();
            assert_eq!(packed.len(), count);
            for (i, ((problem, params), out)) in entries.iter().zip(&packed).enumerate() {
                let solo = solve_with(problem, params, EngineSelect::Rtl).unwrap();
                assert_eq!(out.engine, "rtl", "packing must stay on the rtl fabric");
                assert!(out.noise_applied, "packed lanes must anneal");
                assert!(
                    out.hardware.is_some(),
                    "case {case} entry {i}: packed rtl block must meter its share"
                );
                assert_bit_exact(
                    &format!("case {case} bucket {bucket} chunk {chunk} entry {i}"),
                    out,
                    &solo,
                );
            }
        }
    }
}

#[test]
fn prop_rtl_packed_blocks_meter_solo_cycles_exactly() {
    // A packed block's per-block SerialMac meter must price exactly
    // what the dedicated single-device engine bills for the same
    // problem — the gate behind the `--rtl-packed` bench row's
    // throughput claim.
    let mut rng = Rng::new(8102);
    let chunk = 8usize;
    let entries: Vec<_> = (0..3)
        .map(|i| {
            let g = Graph::random(8, 0.4, &mut rng);
            (
                max_cut(&g),
                PortfolioParams {
                    replicas: 2,
                    max_periods: chunk * 6,
                    seed: 4400 + i,
                    chunk,
                    ..Default::default()
                },
            )
        })
        .collect();
    let mut engine = RtlEngine::new(NetworkConfig::paper(8), 6, chunk);
    let packed = solve_packed(&mut engine, &entries).unwrap();
    for (i, ((problem, params), out)) in entries.iter().zip(&packed).enumerate() {
        let solo = solve_with(problem, params, EngineSelect::Rtl).unwrap();
        assert_bit_exact(&format!("equal-size entry {i}"), out, &solo);
        let hp = out.hardware.as_ref().expect("packed block meters");
        let hs = solo.hardware.as_ref().expect("solo rtl meters");
        assert_eq!(
            hp.fast_cycles, hs.fast_cycles,
            "entry {i}: packed block billed different emulated cycles than solo"
        );
        assert_eq!(hp.sync_fast_cycles, 0, "one device has no all-gather");
    }
}

#[test]
fn prop_rtl_packed_backfill_matches_solo() {
    // More problems than the engine has lanes, with a zero-J instance
    // mixed in so retirement is uneven: overflow entries wait in the
    // queue and backfill lanes as earlier blocks retire.  Every problem
    // — resident or backfilled — must match its solo `--rtl` run, which
    // in particular requires the backfilled block to restart the kick
    // stream on the reused lanes.
    let mut rng = Rng::new(8103);
    for case in 0..3 {
        let chunk = 8;
        let mut entries: Vec<_> = (0..4).map(|_| random_entry_at(&mut rng, chunk, 8)).collect();
        entries.insert(
            1,
            (
                IsingProblem::new(8),
                PortfolioParams {
                    replicas: 2,
                    max_periods: chunk * 12,
                    seed: 7700 + case,
                    chunk,
                    ..Default::default()
                },
            ),
        );
        let max_block = entries.iter().map(|(_, p)| p.replicas).max().unwrap();
        let total: usize = entries.iter().map(|(_, p)| p.replicas).sum();
        // Capacity for roughly half the mix forces real backfill.
        let lanes = max_block.max(total / 2);
        let mut engine = RtlEngine::new(NetworkConfig::paper(8), lanes, chunk);
        let packed = solve_packed(&mut engine, &entries).unwrap();
        assert!(packed[1].early_exit, "zero-J lane should retire early");
        for (i, ((problem, params), out)) in entries.iter().zip(&packed).enumerate() {
            let solo = solve_with(problem, params, EngineSelect::Rtl).unwrap();
            assert_bit_exact(&format!("backfill case {case} entry {i}"), out, &solo);
        }
    }
}

#[test]
fn prop_rtl_padded_block_trajectories_match_a_dedicated_engine() {
    // The lane-bank weight-layout invariant on the bit-true fabric: a
    // block whose problem couples only the first m of the engine's n
    // oscillators (zero-padded bank) must walk the m real oscillators
    // through exactly the trajectory a dedicated m-oscillator engine
    // produces — padded oscillators are uncoupled (frozen under the
    // deterministic dynamics) and kicks are per-oscillator independent
    // of the engine width.  Settle flags are deliberately NOT compared
    // here: the rtl judge reads relative phases over the whole lane,
    // padding included, and the outcome-level identity is held by the
    // exact-bucket mixes above.
    let mut rng = Rng::new(8106);
    for case in 0..4 {
        let m = 5 + rng.usize_below(4); // 5..=8 real oscillators
        let n = 16;
        let w_small = rand_w(&mut rng, m);
        let mut w_padded = vec![0.0f32; n * n];
        for i in 0..m {
            for j in 0..m {
                w_padded[i * n + j] = w_small[i * m + j];
            }
        }
        let lanes = 2usize;
        let mut packed = RtlEngine::new(NetworkConfig::paper(n), 3, 4);
        packed.set_lane_block(0, lanes, &w_padded).unwrap();
        let mut solo = RtlEngine::new(NetworkConfig::paper(m), lanes, 4);
        solo.set_weights(&w_small).unwrap();
        let mut ph = vec![0i32; 3 * n];
        let mut ps = vec![0i32; lanes * m];
        for lane in 0..lanes {
            for i in 0..m {
                let v = rng.range_i64(0, 16) as i32;
                ph[lane * n + i] = v;
                ps[lane * m + i] = v;
            }
        }
        let mut st = vec![-1i32; 3];
        let mut ss = vec![-1i32; lanes];
        for chunk_idx in 0..3i32 {
            let (amp, seed) = (0.7, 900 + case as u64 * 10 + chunk_idx as u64);
            packed.set_lane_block_noise(0, amp, seed).unwrap();
            solo.set_noise(amp, seed).unwrap();
            packed.run_chunk(&mut ph, &mut st, chunk_idx * 4).unwrap();
            solo.run_chunk(&mut ps, &mut ss, chunk_idx * 4).unwrap();
            for lane in 0..lanes {
                assert_eq!(
                    &ph[lane * n..lane * n + m],
                    &ps[lane * m..(lane + 1) * m],
                    "case {case} m={m} lane {lane} chunk {chunk_idx}: \
                     padded trajectories diverged from the dedicated engine"
                );
            }
        }
    }
}

#[test]
fn regression_rtl_backfilled_block_restarts_the_kick_stream() {
    // The backfill regression on the bit-true engine: a lane block that
    // is cleared and re-programmed (what backfilling a retired lane
    // does) must start a FRESH block-local kick stream, not resume the
    // retired problem's tick counter.  Zero couplings freeze the
    // deterministic dynamics, so any phase motion is exactly the noise.
    let cfg = NetworkConfig::paper(6);
    let w = vec![0.0f32; 36];
    let init: Vec<i32> = vec![1, 5, 9, 2, 6, 10, 3, 7, 11, 4, 8, 12];
    let fresh = {
        let mut e = RtlEngine::new(cfg, 2, 4);
        e.set_lane_block(0, 2, &w).unwrap();
        e.set_lane_block_noise(0, 0.9, 7).unwrap();
        let mut ph = init.clone();
        let mut st = vec![-1i32; 2];
        e.run_chunk(&mut ph, &mut st, 0).unwrap();
        ph
    };
    assert_ne!(fresh, init, "amplitude 0.9 must move zero-J phases");

    let mut e = RtlEngine::new(cfg, 2, 4);
    e.set_lane_block(0, 2, &w).unwrap();
    e.set_lane_block_noise(0, 0.9, 7).unwrap();
    let mut ph = init.clone();
    let mut st = vec![-1i32; 2];
    e.run_chunk(&mut ph, &mut st, 0).unwrap();
    assert_eq!(ph, fresh, "first chunk replays the fresh stream");
    // Sensitivity check: WITHOUT re-programming, the block's tick
    // counter keeps advancing — a second chunk from the same start must
    // differ from the first, so the assertion below has teeth.
    let mut ph2 = init.clone();
    let mut st2 = vec![-1i32; 2];
    e.run_chunk(&mut ph2, &mut st2, 4).unwrap();
    assert_ne!(ph2, fresh, "tick counter must advance within a block");
    // Retire + backfill the same lanes: the stream must restart.
    e.clear_lane_block(0).unwrap();
    e.set_lane_block(0, 2, &w).unwrap();
    e.set_lane_block_noise(0, 0.9, 7).unwrap();
    let mut ph3 = init.clone();
    let mut st3 = vec![-1i32; 2];
    e.run_chunk(&mut ph3, &mut st3, 0).unwrap();
    assert_eq!(
        ph3, fresh,
        "backfilled block inherited the retired lane's tick counter"
    );
}

#[test]
fn prop_rtl_cluster_bit_exact_at_every_chunk() {
    // Row-splitting the quantized weight memory across K emulated
    // devices must change nothing about the dynamics: phases and settle
    // flags equal the single-device engine at EVERY chunk, noise on,
    // for K = 2..=4 — including splits that do not divide the row
    // count.  The mid-run noise re-seeding mirrors what the annealing
    // portfolio does between chunks.
    let mut rng = Rng::new(8104);
    for case in 0..4 {
        let n = 7 + rng.usize_below(7); // 7..=13
        for shards in [2usize, 3, 4] {
            let cfg = NetworkConfig::paper(n);
            let w = rand_w(&mut rng, n);
            let batch = 2;
            let mut solo = RtlEngine::new(cfg, batch, 4);
            let mut cl = RtlClusterEngine::new(cfg, shards, batch, 4).unwrap();
            solo.set_weights(&w).unwrap();
            cl.set_weights(&w).unwrap();
            let init: Vec<i32> = (0..batch * n).map(|_| rng.range_i64(0, 16) as i32).collect();
            let (mut pa, mut pb) = (init.clone(), init);
            let (mut sa, mut sb) = (vec![-1i32; batch], vec![-1i32; batch]);
            for (chunk, &level) in [0.9, 0.5, 0.2, 0.0].iter().enumerate() {
                let seed = rng.next_u64();
                solo.set_noise(level, seed).unwrap();
                cl.set_noise(level, seed).unwrap();
                let p0 = (chunk * 4) as i32;
                solo.run_chunk(&mut pa, &mut sa, p0).unwrap();
                cl.run_chunk(&mut pb, &mut sb, p0).unwrap();
                assert_eq!(
                    pb, pa,
                    "case {case} n={n} shards={shards} chunk {chunk}: phases diverged"
                );
                assert_eq!(sb, sa, "case {case} n={n} shards={shards} chunk {chunk}");
            }
            // One priced all-gather per lane-period stepped; a single
            // device never pays one.
            assert_eq!(cl.sync_rounds(), (batch * 4 * 4) as u64);
            assert_eq!(solo.sync_rounds(), 0);
        }
    }
}

#[test]
fn prop_rtl_cluster_solve_outcome_bit_identical() {
    // End to end through the annealed replica portfolio: the K-device
    // cluster answers exactly like one big device at the same seed —
    // what it changes is the hardware bill, which must carry the priced
    // per-period phase all-gathers on top of the solo compute cycles.
    let mut rng = Rng::new(8105);
    let g = Graph::random(11, 0.4, &mut rng); // 2, 3, 4 all non-dividing
    let problem = max_cut(&g);
    let m = problem.embed_dim();
    let params = PortfolioParams {
        replicas: 3,
        max_periods: 40,
        seed: 515,
        ..Default::default()
    };
    let solo = solve_with(&problem, &params, EngineSelect::Rtl).unwrap();
    let hs = solo.hardware.as_ref().expect("solo rtl meters");
    assert_eq!(hs.sync_fast_cycles, 0);
    for shards in [2usize, 3, 4] {
        let out = solve_with(&problem, &params, EngineSelect::RtlCluster { shards }).unwrap();
        let case = format!("shards {shards}");
        assert_eq!(out.engine, "rtl-cluster", "{case}");
        assert_bit_exact(&case, &out, &solo);
        assert_eq!(
            out.quantization_error.to_bits(),
            solo.quantization_error.to_bits(),
            "{case}: row splits must not re-quantize"
        );
        assert_eq!(out.sync_rounds, (out.replicas * out.periods) as u64, "{case}");
        // Lockstep serial MACs: a cluster buys capacity, not speed —
        // per-device compute equals the solo elapsed cycles, and the
        // premium is exactly lane-periods x the per-period sync price.
        let hc = out.hardware.as_ref().expect("cluster meters");
        let phase_bits = NetworkConfig::paper(m).phase_bits;
        let sync = out.sync_rounds * cluster_sync_cycles(shards, m, phase_bits);
        assert!(sync > 0, "{case}: all-gathers must be priced");
        assert_eq!(hc.sync_fast_cycles, sync, "{case}");
        assert_eq!(hc.fast_cycles, hs.fast_cycles + sync, "{case}");
    }
}
