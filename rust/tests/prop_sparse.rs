//! Property tests for the sparse (CSR) coupling fabric: installing the
//! same symmetric weights through `set_weights_sparse` must reproduce
//! the dense matrix kernel bit for bit — with the annealing phase noise
//! enabled — at every period, on the native engine and on row-sharded
//! clusters (non-dividing splits included), across random graphs at
//! densities 0.02..=0.5.  End to end, a sparse-form `IsingProblem` must
//! solve to the exact outcome of its dense-form twin (energies, spins,
//! phases, periods, and the quantization-error report, all bitwise),
//! and the warm engine arena must never hand a dense fabric to a sparse
//! solve or vice versa.

use onn_scale::coordinator::arena::{ArenaKey, EngineArena};
use onn_scale::coordinator::metrics::Metrics;
use onn_scale::onn::config::NetworkConfig;
use onn_scale::onn::sparse::SparseWeights;
use onn_scale::runtime::native::NativeEngine;
use onn_scale::runtime::sharded::ShardedEngine;
use onn_scale::runtime::ChunkEngine;
use onn_scale::solver::portfolio::{
    build_engine, solve_native, solve_portfolio, solve_with, wants_sparse, EngineSelect,
    PortfolioParams, SPARSE_DENSITY_THRESHOLD,
};
use onn_scale::solver::reductions::{max_cut, max_cut_sparse};
use onn_scale::solver::Graph;
use onn_scale::util::rng::Rng;

/// One random symmetric zero-diagonal weight matrix at roughly the
/// requested density, in both fabric forms: the dense f32 payload
/// `set_weights` takes and the CSR payload `set_weights_sparse` takes.
fn rand_sparse_pair(rng: &mut Rng, n: usize, density: f64) -> (Vec<f32>, SparseWeights) {
    let mut dense = vec![0f32; n * n];
    let mut trips: Vec<(usize, usize, i8)> = Vec::new();
    for i in 0..n {
        for j in (i + 1)..n {
            if rng.f64() >= density {
                continue;
            }
            let v = rng.range_i64(-16, 16) as i8;
            if v == 0 {
                continue;
            }
            dense[i * n + j] = v as f32;
            dense[j * n + i] = v as f32;
            trips.push((i, j, v));
            trips.push((j, i, v));
        }
    }
    let sw = SparseWeights::from_triplets(n, &trips).expect("valid symmetric triplets");
    (dense, sw)
}

#[test]
fn prop_native_sparse_fabric_bit_exact_at_every_period() {
    let mut rng = Rng::new(7001);
    for case in 0..20 {
        let n = 4 + rng.usize_below(25); // 4..=28
        let density = 0.02 + rng.f64() * 0.48;
        let cfg = NetworkConfig::paper(n);
        let batch = 1 + rng.usize_below(3);
        // chunk = 1: every run_chunk is one period, so the walk below
        // compares the noisy trajectories period by period.
        let mut dense_eng = NativeEngine::new(cfg, batch, 1);
        let mut sparse_eng = NativeEngine::new(cfg, batch, 1);
        let (w, sw) = rand_sparse_pair(&mut rng, n, density);
        dense_eng.set_weights(&w).unwrap();
        sparse_eng.set_weights_sparse(&sw).unwrap();
        let amplitude = 0.2 + rng.f64() * 0.8;
        let seed = rng.next_u64();
        dense_eng.set_noise(amplitude, seed).unwrap();
        sparse_eng.set_noise(amplitude, seed).unwrap();
        let init: Vec<i32> = (0..batch * n).map(|_| rng.range_i64(0, 16) as i32).collect();
        let (mut pa, mut pb) = (init.clone(), init);
        let (mut sa, mut sb) = (vec![-1i32; batch], vec![-1i32; batch]);
        for period in 0..10 {
            dense_eng.run_chunk(&mut pa, &mut sa, period).unwrap();
            sparse_eng.run_chunk(&mut pb, &mut sb, period).unwrap();
            assert_eq!(
                pa, pb,
                "case {case} n={n} density {density:.3} period {period}: phases diverged"
            );
            assert_eq!(
                sa, sb,
                "case {case} n={n} density {density:.3} period {period}: settle flags diverged"
            );
        }
    }
}

#[test]
fn prop_sharded_sparse_fabric_matches_dense_native() {
    // The CSR is shared read-only across shard workers, each walking
    // its own global row range — including splits that do not divide
    // the row count.  The mid-run noise re-seeding mirrors what the
    // annealing portfolio does between chunks.
    let mut rng = Rng::new(7002);
    for case in 0..10 {
        let n = 5 + rng.usize_below(18); // 5..=22
        let density = 0.02 + rng.f64() * 0.48;
        for shards in [2usize, 3, 5] {
            let shards = shards.min(n);
            let cfg = NetworkConfig::paper(n);
            let mut dense_eng = NativeEngine::new(cfg, 2, 4);
            let mut sharded = ShardedEngine::unprogrammed(cfg, shards, 2, 4).unwrap();
            let (w, sw) = rand_sparse_pair(&mut rng, n, density);
            dense_eng.set_weights(&w).unwrap();
            sharded.set_weights_sparse(&sw).unwrap();
            let init: Vec<i32> = (0..2 * n).map(|_| rng.range_i64(0, 16) as i32).collect();
            let (mut pa, mut pb) = (init.clone(), init);
            let (mut sa, mut sb) = (vec![-1i32; 2], vec![-1i32; 2]);
            for (chunk, &level) in [0.9, 0.5, 0.2, 0.0].iter().enumerate() {
                let seed = rng.next_u64();
                dense_eng.set_noise(level, seed).unwrap();
                sharded.set_noise(level, seed).unwrap();
                dense_eng.run_chunk(&mut pa, &mut sa, (chunk * 4) as i32).unwrap();
                sharded.run_chunk(&mut pb, &mut sb, (chunk * 4) as i32).unwrap();
                assert_eq!(
                    pa, pb,
                    "case {case} n={n} shards={shards} chunk {chunk}: phases diverged"
                );
                assert_eq!(sa, sb, "case {case} n={n} shards={shards} chunk {chunk}");
            }
        }
    }
}

#[test]
fn prop_sparse_form_solve_outcome_bit_identical() {
    // End to end through the annealed replica portfolio: the sparse
    // coupling form must change *nothing* about the answer — only
    // which weight fabric served it.  Densities straddle the engine
    // selection threshold, so both the CSR kernel and the dense
    // fallback (density too high to bother) are exercised.
    let mut rng = Rng::new(7003);
    let edge_probs = [0.02, 0.1, 0.2, 0.35, 0.5];
    let (mut sparse_runs, mut dense_fallbacks) = (0usize, 0usize);
    for case in 0..6u64 {
        let n = 8 + rng.usize_below(10); // 8..=17
        let g = Graph::random(n, edge_probs[case as usize % edge_probs.len()], &mut rng);
        let dense_form = max_cut(&g);
        let sparse_form = max_cut_sparse(&g);
        let params = PortfolioParams {
            replicas: 6,
            max_periods: 48,
            seed: 6000 + case,
            ..Default::default()
        };
        let reference = solve_native(&dense_form, &params).unwrap();
        assert!(!reference.sparse, "dense-form problems never take the CSR kernel");
        let expect_sparse = wants_sparse(&sparse_form);
        if expect_sparse {
            assert!(sparse_form.coupling_density() <= SPARSE_DENSITY_THRESHOLD);
            sparse_runs += 1;
        } else {
            dense_fallbacks += 1;
        }
        for (tag, select) in [
            ("native", EngineSelect::Native),
            ("sharded", EngineSelect::Sharded { shards: 3 }),
        ] {
            let out = solve_with(&sparse_form, &params, select).unwrap();
            assert_eq!(
                out.best_energy.to_bits(),
                reference.best_energy.to_bits(),
                "case {case} {tag}: energies diverged"
            );
            assert_eq!(out.best_spins, reference.best_spins, "case {case} {tag}");
            assert_eq!(out.best_phases, reference.best_phases, "case {case} {tag}");
            assert_eq!(out.periods, reference.periods, "case {case} {tag}");
            assert_eq!(out.settled_replicas, reference.settled_replicas, "case {case} {tag}");
            assert_eq!(
                out.quantization_error.to_bits(),
                reference.quantization_error.to_bits(),
                "case {case} {tag}: the CSR embedding must round exactly like the dense one"
            );
            // The sharded fabric supports CSR too, so the flag depends
            // only on the density threshold.
            assert_eq!(out.sparse, expect_sparse, "case {case} {tag}");
        }
    }
    assert!(
        sparse_runs > 0 && dense_fallbacks > 0,
        "the density spread must exercise both the CSR kernel ({sparse_runs}) \
         and the dense fallback ({dense_fallbacks})"
    );
}

#[test]
fn prop_arena_mixed_dense_sparse_serving_is_bit_identical() {
    // The serving regression of the issue: a warm dense engine checked
    // out for a sparse solve (or vice versa) would reprogram across
    // fabric kinds.  With `sparse` in the ArenaKey the two populations
    // stay separate, and every warm solve is bit-identical to its cold
    // reference — interleaved dense/sparse traffic included.
    let mut rng = Rng::new(7004);
    let g = Graph::random(14, 0.15, &mut rng);
    let dense_form = max_cut(&g);
    let sparse_form = max_cut_sparse(&g);
    assert!(wants_sparse(&sparse_form), "low-density instance must take the CSR kernel");
    let params = PortfolioParams {
        replicas: 4,
        max_periods: 32,
        seed: 77,
        ..Default::default()
    };
    let cold_dense = solve_native(&dense_form, &params).unwrap();
    let cold_sparse = solve_native(&sparse_form, &params).unwrap();
    assert_eq!(cold_dense.best_energy.to_bits(), cold_sparse.best_energy.to_bits());

    let metrics = Metrics::new();
    let mut arena = EngineArena::new(2);
    let m = dense_form.embed_dim();
    let (batch, chunk) = (params.replicas, params.chunk);
    let select = EngineSelect::Native;
    for round in 0..2 {
        for (tag, problem, cold) in [
            ("dense", &dense_form, &cold_dense),
            ("sparse", &sparse_form, &cold_sparse),
        ] {
            let key = ArenaKey::for_solve(m, batch, chunk, select, wants_sparse(problem), None);
            let mut engine = arena
                .checkout(key, &metrics, || build_engine(m, batch, chunk, select))
                .unwrap();
            let out = solve_portfolio(engine.as_mut(), problem, &params).unwrap();
            arena.checkin(key, engine, &metrics);
            assert_eq!(
                out.best_energy.to_bits(),
                cold.best_energy.to_bits(),
                "round {round} {tag}: warm solve diverged from cold"
            );
            assert_eq!(out.best_spins, cold.best_spins, "round {round} {tag}");
            assert_eq!(out.best_phases, cold.best_phases, "round {round} {tag}");
            assert_eq!(out.periods, cold.periods, "round {round} {tag}");
            assert_eq!(out.sparse, wants_sparse(problem), "round {round} {tag}");
        }
    }
    let snap = metrics.snapshot();
    assert_eq!(snap.arena_misses, 2, "one cold build per fabric, never shared");
    assert_eq!(
        snap.arena_hits, 2,
        "round two must reuse each fabric's own warm engine"
    );
}
