//! Table 6/7-shaped integration checks: the retrieval sweep driver must
//! reproduce the paper's qualitative structure at reduced trial counts.

use onn_scale::harness::datasets::benchmark_by_name;
use onn_scale::harness::retrieval::{run_cell, Engine};

#[test]
fn accuracy_monotone_in_corruption_small_sizes() {
    for name in ["3x3", "5x4"] {
        let set = benchmark_by_name(name).unwrap();
        let a10 = run_cell(&set, 10.0, 25, 1, Engine::Native).unwrap();
        let a25 = run_cell(&set, 25.0, 25, 1, Engine::Native).unwrap();
        let a50 = run_cell(&set, 50.0, 25, 1, Engine::Native).unwrap();
        assert!(
            a10.accuracy_pct() + 1e-9 >= a25.accuracy_pct(),
            "{name}: 10% {:.1} < 25% {:.1}",
            a10.accuracy_pct(),
            a25.accuracy_pct()
        );
        assert!(
            a25.accuracy_pct() + 1e-9 >= a50.accuracy_pct(),
            "{name}: 25% {:.1} < 50% {:.1}",
            a25.accuracy_pct(),
            a50.accuracy_pct()
        );
    }
}

#[test]
fn low_corruption_high_accuracy_all_sizes() {
    // Paper Table 6: 10% corruption retrieves at or near 100% on every
    // dataset, including the large ones only the hybrid can run.
    for name in ["3x3", "5x4", "7x6", "10x10"] {
        let set = benchmark_by_name(name).unwrap();
        let cell = run_cell(&set, 10.0, 15, 2, Engine::Native).unwrap();
        assert!(
            cell.accuracy_pct() >= 80.0,
            "{name} @10%: {:.1}%",
            cell.accuracy_pct()
        );
    }
}

#[test]
fn architectures_agree_on_moderate_noise() {
    // Table 6's central claim, at test scale: RA (RTL) vs HA (native
    // functional) accuracies close on the small datasets.
    let set = benchmark_by_name("5x4").unwrap();
    let ra = run_cell(&set, 25.0, 20, 3, Engine::RtlRecurrent).unwrap();
    let ha = run_cell(&set, 25.0, 20, 3, Engine::Native).unwrap();
    let diff = (ra.accuracy_pct() - ha.accuracy_pct()).abs();
    assert!(
        diff <= 20.0,
        "architectures diverged: RA {:.1}% vs HA {:.1}%",
        ra.accuracy_pct(),
        ha.accuracy_pct()
    );
}

#[test]
fn settle_time_grows_with_corruption() {
    // Paper Table 7: harder inputs take longer to settle (weak
    // monotonicity; allow small-sample slack).
    let set = benchmark_by_name("7x6").unwrap();
    let a10 = run_cell(&set, 10.0, 20, 4, Engine::Native).unwrap();
    let a50 = run_cell(&set, 50.0, 20, 4, Engine::Native).unwrap();
    assert!(
        a50.mean_settle + 2.0 >= a10.mean_settle,
        "settle: 10% {:.1} vs 50% {:.1}",
        a10.mean_settle,
        a50.mean_settle
    );
}

#[test]
fn deterministic_given_seed() {
    let set = benchmark_by_name("3x3").unwrap();
    let a = run_cell(&set, 25.0, 20, 7, Engine::Native).unwrap();
    let b = run_cell(&set, 25.0, 20, 7, Engine::Native).unwrap();
    assert_eq!(a, b, "same seed must reproduce the same cell");
    let c = run_cell(&set, 25.0, 20, 8, Engine::Native).unwrap();
    assert_eq!(a.trials, c.trials);
}

#[test]
fn rtl_hybrid_cell_runs() {
    let set = benchmark_by_name("3x3").unwrap();
    let cell = run_cell(&set, 10.0, 10, 5, Engine::RtlHybrid).unwrap();
    assert_eq!(cell.trials, 20);
    assert!(cell.accuracy_pct() >= 80.0, "{:.1}", cell.accuracy_pct());
}
