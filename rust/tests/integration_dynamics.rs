//! Cross-engine integration tests: the functional (period-snap) engine,
//! the naive oracle, and both cycle-accurate RTL simulators must tell
//! one consistent story about the ONN dynamics.

use onn_scale::harness::datasets::benchmark_by_name;
use onn_scale::onn::config::NetworkConfig;
use onn_scale::onn::dynamics::{period_step_naive, FunctionalEngine};
use onn_scale::onn::learning::{is_fixed_point, train_quantized};
use onn_scale::onn::phase::{spin_to_phase, state_to_spins};
use onn_scale::onn::weights::WeightMatrix;
use onn_scale::rtl::hybrid::HybridOnn;
use onn_scale::rtl::recurrent::RecurrentOnn;
use onn_scale::rtl::RtlSim;
use onn_scale::util::rng::Rng;

fn rand_weights(rng: &mut Rng, n: usize) -> WeightMatrix {
    let mut w = WeightMatrix::zeros(n);
    for i in 0..n {
        for j in 0..n {
            w.set(i, j, rng.range_i64(-16, 16) as i8);
        }
    }
    w
}

#[test]
fn functional_engine_matches_naive_oracle_many_sizes() {
    let mut rng = Rng::new(1);
    for n in [1, 2, 3, 7, 16, 31, 48, 64] {
        let cfg = NetworkConfig::paper(n);
        let w = rand_weights(&mut rng, n);
        let mut eng = FunctionalEngine::new(cfg, w.clone());
        for _ in 0..3 {
            let ph0: Vec<i32> = (0..n).map(|_| rng.range_i64(0, 16) as i32).collect();
            let want = period_step_naive(&cfg, &w, &ph0);
            let mut got = ph0.clone();
            eng.period_step(&mut got);
            assert_eq!(got, want, "n={n}");
        }
    }
}

#[test]
fn stored_patterns_stable_in_all_engines() {
    let set = benchmark_by_name("3x3").unwrap();
    let cfg = set.cfg;
    let p = cfg.period() as i32;
    let mut functional = FunctionalEngine::new(cfg, set.weights.clone());
    let mut ra = RecurrentOnn::new(cfg, set.weights.clone());
    let mut ha = HybridOnn::new(cfg, set.weights.clone());
    for pat in &set.dataset.patterns {
        assert!(is_fixed_point(&set.weights, &pat.spins));
        let phases: Vec<i32> = pat.spins.iter().map(|&s| spin_to_phase(s, p)).collect();

        let out = functional.run_to_settle(&phases, 16);
        assert_eq!(out.settled, Some(0), "functional: stored pattern moved");

        for (name, sim) in [("ra", &mut ra as &mut dyn RtlSim), ("ha", &mut ha)] {
            sim.set_phases(&phases);
            let out = sim.run_to_settle(30);
            assert!(out.settled.is_some(), "{name}: did not settle");
            let rel: Vec<i8> = pat.spins.iter().map(|&s| s * pat.spins[0]).collect();
            assert_eq!(
                state_to_spins(&out.phases, p),
                rel,
                "{name}: stored pattern moved"
            );
        }
    }
}

#[test]
fn rtl_recurrent_agrees_with_functional_on_retrieval_statistics() {
    // The functional engine implements the (synchronized) hybrid
    // semantics at period granularity; the paper's claim is that all
    // these implementations retrieve (nearly) identically.
    let set = benchmark_by_name("5x4").unwrap();
    let p = set.cfg.period() as i32;
    let mut functional = FunctionalEngine::new(set.cfg, set.weights.clone());
    let mut ra = RecurrentOnn::new(set.cfg, set.weights.clone());
    let mut rng = Rng::new(11);
    let trials = 60;
    let (mut ok_f, mut ok_r) = (0, 0);
    for t in 0..trials {
        let target = &set.dataset.patterns[t % set.dataset.patterns.len()];
        let corrupted = target.corrupt(2, &mut rng);
        let phases: Vec<i32> = corrupted
            .spins
            .iter()
            .map(|&s| spin_to_phase(s, p))
            .collect();
        let fo = functional.run_to_settle(&phases, 256);
        if fo.settled.is_some()
            && target.matches_up_to_inversion(&state_to_spins(&fo.phases, p))
        {
            ok_f += 1;
        }
        ra.set_phases(&phases);
        let ro = ra.run_to_settle(256);
        if ro.settled.is_some()
            && target.matches_up_to_inversion(&state_to_spins(&ro.phases, p))
        {
            ok_r += 1;
        }
    }
    assert!(ok_f > trials / 2, "functional retrieval broken: {ok_f}/{trials}");
    assert!(ok_r > trials / 2, "RTL retrieval broken: {ok_r}/{trials}");
    assert!(
        (ok_f as i32 - ok_r as i32).abs() <= trials as i32 / 5,
        "engines diverged: functional {ok_f} vs rtl {ok_r}"
    );
}

#[test]
fn hybrid_rtl_binary_fixed_points_match_functional() {
    // Binary fixed points of the functional dynamics must be fixed for
    // the (synchronized) hybrid RTL as well.
    let set = benchmark_by_name("3x3").unwrap();
    let p = set.cfg.period() as i32;
    let mut ha = HybridOnn::new(set.cfg, set.weights.clone());
    for pat in &set.dataset.patterns {
        let inv: Vec<i8> = pat.spins.iter().map(|&s| -s).collect();
        for state in [&pat.spins, &inv] {
            let phases: Vec<i32> = state.iter().map(|&s| spin_to_phase(s, p)).collect();
            ha.set_phases(&phases);
            let out = ha.run_to_settle(20);
            assert!(out.settled.is_some());
            let rel_want: Vec<i8> = state.iter().map(|&s| s * state[0]).collect();
            assert_eq!(state_to_spins(&out.phases, p), rel_want);
        }
    }
}

#[test]
fn settle_times_land_in_paper_band() {
    // Paper Table 7: settle times in the ~10-36 period band for
    // converging retrievals (our absolute values differ, but orders of
    // magnitude must agree: not 1000).
    let set = benchmark_by_name("7x6").unwrap();
    let p = set.cfg.period() as i32;
    let mut eng = FunctionalEngine::new(set.cfg, set.weights.clone());
    let mut rng = Rng::new(5);
    let mut settles = Vec::new();
    for t in 0..50 {
        let target = &set.dataset.patterns[t % 5];
        let corrupted = target.corrupt(target.corruption_count(25.0), &mut rng);
        let phases: Vec<i32> = corrupted
            .spins
            .iter()
            .map(|&s| spin_to_phase(s, p))
            .collect();
        if let Some(s) = eng.run_to_settle(&phases, 256).settled {
            settles.push(s as f64);
        }
    }
    assert!(!settles.is_empty());
    let mean = onn_scale::util::stats::mean(&settles);
    assert!(
        (0.5..=64.0).contains(&mean),
        "mean settle {mean} outside plausible band"
    );
}

#[test]
fn serialization_cost_scales_linearly_with_n() {
    // The hybrid design's defining trade-off: fast-clock cycles per
    // phase update grow ~N (frequency division, paper section 5.1).
    for n in [8, 64, 506] {
        let sim = HybridOnn::new(NetworkConfig::paper(n), WeightMatrix::zeros(n));
        assert_eq!(sim.fast_cycles_per_update(), n + 6);
    }
}

#[test]
fn quantization_preserves_retrieval_on_all_datasets() {
    // Every paper dataset: trained + quantized weights keep all stored
    // patterns as strict fixed points (the premise of Table 6).
    for name in ["3x3", "5x4", "7x6", "10x10", "22x22"] {
        let set = benchmark_by_name(name).unwrap();
        for pat in &set.dataset.patterns {
            assert!(
                is_fixed_point(&set.weights, &pat.spins),
                "{name}: '{}' unstable after quantization",
                pat.name
            );
        }
    }
}

#[test]
fn train_quantized_roundtrip_small() {
    let mut rng = Rng::new(3);
    let pats: Vec<Vec<i8>> = (0..3)
        .map(|_| (0..12).map(|_| rng.spin()).collect())
        .collect();
    let cfg = NetworkConfig::paper(12);
    let w = train_quantized(&pats, &cfg);
    let mut eng = FunctionalEngine::new(cfg, w);
    for p0 in &pats {
        let phases: Vec<i32> = p0.iter().map(|&s| spin_to_phase(s, 16)).collect();
        let out = eng.run_to_settle(&phases, 8);
        assert_eq!(out.settled, Some(0));
    }
}
