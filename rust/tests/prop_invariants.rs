//! Property-based tests (hand-rolled proptest substitute): hundreds of
//! randomized cases per invariant, deterministic seeds, shrink-free but
//! with full case reporting on failure.

use onn_scale::onn::config::NetworkConfig;
use onn_scale::onn::dynamics::{period_step_naive, FunctionalEngine};
use onn_scale::onn::phase::{
    amplitude, distance, phase_to_spin, spin_to_phase, state_to_spins, wrap,
};
use onn_scale::onn::weights::WeightMatrix;
use onn_scale::util::json::Json;
use onn_scale::util::rng::Rng;

const CASES: usize = 200;

fn rand_weights(rng: &mut Rng, n: usize) -> WeightMatrix {
    let mut w = WeightMatrix::zeros(n);
    for i in 0..n {
        for j in 0..n {
            w.set(i, j, rng.range_i64(-16, 16) as i8);
        }
    }
    w
}

#[test]
fn prop_phase_update_is_rotation_equivariant() {
    let mut rng = Rng::new(1001);
    for case in 0..CASES {
        let n = 1 + rng.usize_below(12);
        let cfg = NetworkConfig::paper(n);
        let w = rand_weights(&mut rng, n);
        let ph0: Vec<i32> = (0..n).map(|_| rng.range_i64(0, 16) as i32).collect();
        let d = rng.range_i64(0, 16) as i32;
        let mut eng = FunctionalEngine::new(cfg, w);
        let mut a = ph0.clone();
        eng.period_step(&mut a);
        let mut b: Vec<i32> = ph0.iter().map(|&x| wrap(x + d, 16)).collect();
        eng.period_step(&mut b);
        let a_rot: Vec<i32> = a.iter().map(|&x| wrap(x + d, 16)).collect();
        assert_eq!(b, a_rot, "case {case}: n={n} d={d} ph0={ph0:?}");
    }
}

#[test]
fn prop_incremental_equals_naive() {
    let mut rng = Rng::new(1002);
    for case in 0..CASES {
        let n = 1 + rng.usize_below(24);
        let cfg = NetworkConfig::paper(n);
        let w = rand_weights(&mut rng, n);
        let ph0: Vec<i32> = (0..n).map(|_| rng.range_i64(0, 16) as i32).collect();
        let want = period_step_naive(&cfg, &w, &ph0);
        let mut got = ph0.clone();
        FunctionalEngine::new(cfg, w).period_step(&mut got);
        assert_eq!(got, want, "case {case}: n={n}");
    }
}

#[test]
fn prop_phases_stay_in_range() {
    let mut rng = Rng::new(1003);
    for _ in 0..CASES {
        let n = 1 + rng.usize_below(10);
        let cfg = NetworkConfig::paper(n);
        let w = rand_weights(&mut rng, n);
        let mut eng = FunctionalEngine::new(cfg, w);
        let mut ph: Vec<i32> = (0..n).map(|_| rng.range_i64(0, 16) as i32).collect();
        for _ in 0..5 {
            eng.period_step(&mut ph);
            assert!(ph.iter().all(|&x| (0..16).contains(&x)), "{ph:?}");
        }
    }
}

#[test]
fn prop_binary_manifold_closed() {
    // Binary phase states stay binary under the dynamics.
    let mut rng = Rng::new(1004);
    for _ in 0..CASES {
        let n = 2 + rng.usize_below(10);
        let cfg = NetworkConfig::paper(n);
        let w = rand_weights(&mut rng, n);
        let mut eng = FunctionalEngine::new(cfg, w);
        let mut ph: Vec<i32> = (0..n).map(|_| spin_to_phase(rng.spin(), 16)).collect();
        for _ in 0..4 {
            eng.period_step(&mut ph);
            assert!(ph.iter().all(|&x| x == 0 || x == 8), "{ph:?}");
        }
    }
}

#[test]
fn prop_amplitude_antiperiodic() {
    // s(t + P/2) == -s(t): square waves are antiperiodic in half a
    // period; everything in the phase algebra leans on this.
    let mut rng = Rng::new(1005);
    for _ in 0..CASES {
        let phi = rng.range_i64(0, 16) as i32;
        let t = rng.range_i64(-64, 64);
        assert_eq!(amplitude(phi, t + 8, 16), -amplitude(phi, t, 16));
        assert_eq!(amplitude(phi, t + 16, 16), amplitude(phi, t, 16));
    }
}

#[test]
fn prop_distance_triangle_inequality() {
    let mut rng = Rng::new(1006);
    for _ in 0..CASES {
        let (a, b, c) = (
            rng.range_i64(0, 16) as i32,
            rng.range_i64(0, 16) as i32,
            rng.range_i64(0, 16) as i32,
        );
        assert!(distance(a, c, 16) <= distance(a, b, 16) + distance(b, c, 16));
    }
}

#[test]
fn prop_spin_readout_consistent_with_distance() {
    let mut rng = Rng::new(1007);
    for _ in 0..CASES {
        let phi = rng.range_i64(0, 16) as i32;
        let r = rng.range_i64(0, 16) as i32;
        let s = phase_to_spin(phi, r, 16);
        let d_ref = distance(phi, r, 16);
        let d_anti = distance(phi, wrap(r + 8, 16), 16);
        if d_ref < d_anti {
            assert_eq!(s, 1);
        } else if d_anti < d_ref {
            assert_eq!(s, -1);
        }
    }
}

#[test]
fn prop_weight_quantization_bounds_and_sign() {
    let mut rng = Rng::new(1008);
    let cfg = NetworkConfig::paper(4);
    for _ in 0..CASES {
        let master: Vec<f32> = (0..16)
            .map(|_| (rng.f64() * 4.0 - 2.0) as f32)
            .collect();
        let w = WeightMatrix::quantize(&master, 4, &cfg);
        for i in 0..4 {
            for j in 0..4 {
                let q = w.get(i, j) as i32;
                assert!((-16..=15).contains(&q));
                let m = master[i * 4 + j];
                if m > 0.05 {
                    assert!(q >= 0, "sign flipped: {m} -> {q}");
                }
                if m < -0.05 {
                    assert!(q <= 0, "sign flipped: {m} -> {q}");
                }
            }
        }
    }
}

#[test]
fn prop_quantize_preserves_structure_at_all_precisions() {
    // The FPGA programming path at every configured precision (3..=8
    // signed weight bits): a symmetric float master quantizes to a
    // symmetric matrix, every entry lands in the two's-complement
    // range, the strongest coupling saturates the positive limit, and
    // the reported rounding loss is bounded by half an LSB.
    let mut rng = Rng::new(1013);
    for case in 0..CASES {
        let weight_bits = 3 + (case % 6) as u32;
        let n = 2 + rng.usize_below(6);
        let mut cfg = NetworkConfig::paper(n);
        cfg.weight_bits = weight_bits;
        let (lo, hi) = cfg.weight_range();
        let mut master = vec![0f32; n * n];
        for i in 0..n {
            for j in 0..=i {
                let v = (rng.f64() * 8.0 - 4.0) as f32;
                master[i * n + j] = v;
                master[j * n + i] = v;
            }
        }
        let (w, err) = WeightMatrix::quantize_with_error(&master, n, &cfg);
        assert!(
            w.is_symmetric(),
            "case {case}: {weight_bits} bits broke symmetry"
        );
        assert!(
            w.as_slice().iter().all(|&q| (lo..=hi).contains(&(q as i32))),
            "case {case}: {weight_bits}-bit entry out of [{lo}, {hi}]"
        );
        assert!(w.max_abs() <= hi, "case {case}: max_abs over the limit");
        let max_abs = master.iter().fold(0f32, |m, x| m.max(x.abs()));
        if max_abs > 0.0 {
            assert_eq!(
                w.max_abs(),
                hi,
                "case {case}: strongest coupling must saturate {hi}"
            );
        }
        assert!(
            (0.0..=0.5 / hi as f64 + 1e-9).contains(&err),
            "case {case}: rounding loss {err} outside [0, half an LSB]"
        );
    }
}

#[test]
fn prop_spin_phase_roundtrip_across_phase_precisions() {
    // The binary encode/readout pair at every phase wheel the config
    // allows (4..=64 steps): canonical phases decode back to their
    // spins, and the relative readout is invariant under the global
    // rotations the quantized dynamics produce.
    let mut rng = Rng::new(1014);
    for case in 0..CASES {
        let phase_bits = 2 + (case % 5) as u32;
        let p = 1i32 << phase_bits;
        for s in [-1i8, 1] {
            assert_eq!(
                phase_to_spin(spin_to_phase(s, p), 0, p),
                s,
                "case {case}: p={p} spin {s} did not round-trip"
            );
        }
        let n = 2 + rng.usize_below(8);
        let spins: Vec<i8> = (0..n).map(|_| rng.spin()).collect();
        let d = rng.range_i64(0, p as i64) as i32;
        let phases: Vec<i32> = spins
            .iter()
            .map(|&s| wrap(spin_to_phase(s, p) + d, p))
            .collect();
        let decoded = state_to_spins(&phases, p);
        let rel: Vec<i8> = spins.iter().map(|&s| s * spins[0]).collect();
        assert_eq!(decoded, rel, "case {case}: p={p} d={d}");
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    let mut rng = Rng::new(1009);
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.usize_below(4) } else { rng.usize_below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool()),
            2 => Json::Num((rng.range_i64(-1_000_000, 1_000_000)) as f64),
            3 => Json::Str(
                (0..rng.usize_below(12))
                    .map(|_| char::from(b'a' + (rng.usize_below(26) as u8)))
                    .collect::<String>()
                    + if rng.bool() { "\"\\\n" } else { "" },
            ),
            4 => Json::Arr((0..rng.usize_below(4)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.usize_below(4))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for case in 0..CASES {
        let v = random_json(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e} in {text}"));
        assert_eq!(back, v, "case {case}: {text}");
    }
}

#[test]
fn prop_corruption_count_and_overlap() {
    use onn_scale::onn::patterns::Pattern;
    let mut rng = Rng::new(1010);
    for _ in 0..CASES {
        let rows = 2 + rng.usize_below(6);
        let cols = 2 + rng.usize_below(6);
        let spins: Vec<i8> = (0..rows * cols).map(|_| rng.spin()).collect();
        let pat = Pattern {
            name: "r".into(),
            rows,
            cols,
            spins,
        };
        let k = rng.usize_below(pat.len() + 1);
        let c = pat.corrupt(k, &mut rng);
        let want_overlap = 1.0 - 2.0 * k as f64 / pat.len() as f64;
        assert!((pat.overlap(&c.spins) - want_overlap).abs() < 1e-9);
    }
}

#[test]
fn prop_router_rejects_mismatched_requests() {
    use onn_scale::coordinator::job::RetrievalRequest;
    use onn_scale::coordinator::metrics::Metrics;
    use onn_scale::coordinator::router::Router;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    let mut rng = Rng::new(1011);
    let router = Router::new(Arc::new(Metrics::default()));
    let (tx, rx) = channel();
    router.register(9, tx).unwrap();
    for _ in 0..CASES {
        let n = 1 + rng.usize_below(20);
        let len = 1 + rng.usize_below(20);
        let req = RetrievalRequest {
            id: 0,
            n,
            phases: vec![0; len],
            max_periods: 8,
        };
        let res = router.submit(req);
        if n != len || n != 9 {
            assert!(res.is_err(), "accepted bad request n={n} len={len}");
        } else {
            assert!(res.is_ok());
            let _ = rx.try_recv();
        }
    }
}

#[test]
fn prop_serial_mac_equals_dot_for_any_row() {
    use onn_scale::rtl::hybrid::SerialMac;
    let mut rng = Rng::new(1012);
    for _ in 0..CASES {
        let n = 1 + rng.usize_below(64);
        let row: Vec<i8> = (0..n).map(|_| rng.range_i64(-16, 16) as i8).collect();
        let amps: Vec<i32> = (0..n).map(|_| rng.spin() as i32).collect();
        let want: i32 = row.iter().zip(&amps).map(|(&w, &a)| w as i32 * a).sum();
        assert_eq!(SerialMac::default().run(&row, &amps), want);
    }
}
