//! Property tests for the solver subsystem: annealing-schedule
//! invariants, QUBO <-> Ising round-trips on brute-forceable instances,
//! noise-hook determinism, and descent/polish contracts.

use onn_scale::solver::anneal::Schedule;
use onn_scale::solver::problem::{spins_to_bits, IsingProblem, Qubo};
use onn_scale::solver::sa::{greedy_descent, is_local_minimum};
use onn_scale::util::rng::Rng;

const CASES: usize = 200;

fn random_schedule(rng: &mut Rng) -> Schedule {
    let start = rng.f64() * 1.5; // may exceed 1: levels must clamp
    match rng.usize_below(3) {
        0 => Schedule::Geometric {
            start,
            factor: rng.f64(),
        },
        1 => Schedule::Linear { start },
        _ => Schedule::Constant { level: start },
    }
}

fn random_ising(rng: &mut Rng, n: usize, with_field: bool) -> IsingProblem {
    let mut p = IsingProblem::new(n);
    for i in 0..n {
        for k in (i + 1)..n {
            p.set_j(i, k, rng.range_i64(-6, 7) as f64);
        }
        if with_field {
            p.h[i] = rng.range_i64(-4, 5) as f64;
        }
    }
    p
}

#[test]
fn prop_schedules_monotone_nonincreasing_and_end_at_zero() {
    let mut rng = Rng::new(2001);
    for case in 0..CASES {
        let s = random_schedule(&mut rng);
        let total = 1 + rng.usize_below(40);
        let levels = s.levels(total);
        assert_eq!(levels.len(), total);
        assert_eq!(
            *levels.last().unwrap(),
            0.0,
            "case {case}: {s:?} total={total} must end noise-free"
        );
        for (k, w) in levels.windows(2).enumerate() {
            assert!(
                w[1] <= w[0] + 1e-12,
                "case {case}: {s:?} rose at chunk {k}: {levels:?}"
            );
        }
        for (k, &l) in levels.iter().enumerate() {
            assert!(
                (0.0..=1.0).contains(&l),
                "case {case}: level {l} at {k} outside [0, 1]"
            );
        }
    }
}

#[test]
fn prop_qubo_ising_objective_identity_on_all_states() {
    // On every state of brute-forceable instances, the converted Ising
    // objective equals the QUBO value exactly.
    let mut rng = Rng::new(2002);
    for case in 0..60 {
        let n = 1 + rng.usize_below(8);
        let mut q = Qubo::new(n);
        for i in 0..n {
            for k in i..n {
                q.add(i, k, rng.range_i64(-8, 9) as f64);
            }
        }
        let p = q.to_ising();
        for mask in 0u64..(1u64 << n) {
            let spins: Vec<i8> = (0..n)
                .map(|i| if mask >> i & 1 == 1 { 1 } else { -1 })
                .collect();
            let x = spins_to_bits(&spins);
            assert!(
                (q.value(&x) - p.objective(&spins)).abs() < 1e-9,
                "case {case} mask {mask}: {} vs {}",
                q.value(&x),
                p.objective(&spins)
            );
        }
    }
}

#[test]
fn prop_qubo_ising_roundtrip_preserves_argmin() {
    // Ising -> QUBO -> Ising on n <= 12: the round-tripped Hamiltonian
    // has the same minimizers (energies shift only by the offset).
    let mut rng = Rng::new(2003);
    for case in 0..40 {
        let n = 2 + rng.usize_below(11); // 2..=12
        let p = random_ising(&mut rng, n, rng.bool());
        let rt = p.to_qubo().to_ising();
        let (argmin, e_min) = p.brute_force();
        let (rt_argmin, rt_min) = rt.brute_force();
        // The original argmin must be optimal for the round-trip too.
        assert!(
            (rt.energy(&argmin) - rt_min).abs() < 1e-9,
            "case {case}: original argmin not optimal after round-trip"
        );
        // And vice versa (degenerate minima may differ as states).
        assert!(
            (p.energy(&rt_argmin) - e_min).abs() < 1e-9,
            "case {case}: round-trip argmin not optimal originally"
        );
    }
}

#[test]
fn prop_embed_decode_roundtrip_on_binary_states() {
    // Embedding to the quantized fabric and decoding relative to the
    // ancilla must invert on canonical binary phase states.
    use onn_scale::onn::phase::spin_to_phase;
    let mut rng = Rng::new(2004);
    for case in 0..CASES {
        let n = 2 + rng.usize_below(10);
        let p = random_ising(&mut rng, n, rng.bool());
        let spins: Vec<i8> = (0..n).map(|_| rng.spin()).collect();
        let mut phases: Vec<i32> = spins.iter().map(|&s| spin_to_phase(s, 16)).collect();
        if p.has_field() {
            phases.push(0); // ancilla at +1
        }
        let decoded = p.decode_spins(&phases, 16);
        let inverted: Vec<i8> = spins.iter().map(|&s| -s).collect();
        if p.has_field() {
            // The ancilla gauge-fixes the decode exactly.
            assert_eq!(decoded, spins, "case {case}");
        } else {
            // Without fields the Hamiltonian is inversion-symmetric, so
            // the decode is defined up to a global flip.
            assert!(
                decoded == spins || decoded == inverted,
                "case {case}: {decoded:?} vs {spins:?}"
            );
        }
        // Global phase inversion decodes identically (gauge symmetry).
        let flipped: Vec<i32> = phases.iter().map(|&x| (x + 8) % 16).collect();
        assert_eq!(p.decode_spins(&flipped, 16), decoded, "case {case} flipped");
    }
}

#[test]
fn prop_greedy_descent_monotone_and_locally_optimal() {
    let mut rng = Rng::new(2005);
    for case in 0..CASES {
        let n = 2 + rng.usize_below(14);
        let p = random_ising(&mut rng, n, rng.bool());
        let mut spins: Vec<i8> = (0..n).map(|_| rng.spin()).collect();
        let before = p.energy(&spins);
        greedy_descent(&p, &mut spins);
        let after = p.energy(&spins);
        assert!(after <= before + 1e-9, "case {case}: {before} -> {after}");
        assert!(is_local_minimum(&p, &spins), "case {case}");
    }
}

#[test]
fn prop_phase_noise_is_deterministic_per_seed() {
    use onn_scale::onn::config::NetworkConfig;
    use onn_scale::onn::dynamics::{FunctionalEngine, PhaseNoise};
    use onn_scale::onn::weights::WeightMatrix;
    let mut rng = Rng::new(2006);
    for case in 0..40 {
        let n = 2 + rng.usize_below(8);
        let cfg = NetworkConfig::paper(n);
        let mut w = WeightMatrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                w.set(i, j, rng.range_i64(-16, 16) as i8);
            }
        }
        let amplitude = rng.f64();
        let seed = rng.next_u64();
        let ph0: Vec<i32> = (0..n).map(|_| rng.range_i64(0, 16) as i32).collect();
        let run = |w: WeightMatrix, ph0: &[i32]| {
            let mut eng = FunctionalEngine::new(cfg, w);
            eng.set_noise(Some(PhaseNoise::new(amplitude, seed)));
            let mut ph = ph0.to_vec();
            for _ in 0..6 {
                eng.period_step(&mut ph);
            }
            ph
        };
        let a = run(w.clone(), &ph0);
        let b = run(w, &ph0);
        assert_eq!(a, b, "case {case}: same seed must reproduce");
        assert!(a.iter().all(|&x| (0..16).contains(&x)), "case {case}");
    }
}

#[test]
fn prop_vertex_cover_reduction_optimum_is_minimum_cover() {
    use onn_scale::solver::graph::Graph;
    use onn_scale::solver::reductions::{cover_size, decode_cover, is_cover, min_vertex_cover};
    let mut rng = Rng::new(2007);
    for case in 0..25 {
        let n = 3 + rng.usize_below(6); // 3..=8
        let g = Graph::random(n, 0.4, &mut rng);
        let p = min_vertex_cover(&g, 2.0);
        let (spins, _) = p.brute_force();
        let cover = decode_cover(&g, &spins);
        assert!(is_cover(&g, &cover), "case {case}");
        // Exhaustive minimum cover for comparison.
        let mut best = usize::MAX;
        for mask in 0u64..(1u64 << n) {
            let cand: Vec<bool> = (0..n).map(|i| mask >> i & 1 == 1).collect();
            if is_cover(&g, &cand) {
                best = best.min(cand.iter().filter(|&&b| b).count());
            }
        }
        assert_eq!(
            cover_size(&cover),
            best,
            "case {case}: reduction optimum is not a minimum cover"
        );
    }
}
