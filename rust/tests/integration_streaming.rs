//! Serving-path lifecycle integration: the evented streaming front end
//! (`serve_evented`), the warm engine arena's bit-identity contract,
//! and the `serve_tcp` shutdown regression.
//!
//! These are the proof obligations of DESIGN_SOLVER.md §10: a client
//! disconnect cancels its in-flight anneal and frees the worker, an
//! arena-served (warm, reprogrammed) solve answers byte-for-byte like a
//! cold-engine solve at equal seed on every fabric, a malformed-line
//! flood on one connection never stalls another, and the accept loop
//! exits on shutdown without needing one last client to connect.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use onn_scale::coordinator::batcher::BatchPolicy;
use onn_scale::coordinator::server::{handle_line, serve_tcp, Coordinator, SolverPoolConfig};
use onn_scale::coordinator::stream::serve_evented;
use onn_scale::solver::graph::Graph;
use onn_scale::util::json::Json;
use onn_scale::util::rng::Rng;

/// JSON-lines solve request for a graph with J = -1 couplings (max-cut
/// sign convention), optionally streaming progress lines.
fn solve_line(
    id: u64,
    g: &Graph,
    replicas: usize,
    max_periods: usize,
    seed: u64,
    stream: bool,
) -> String {
    let edges = Json::Arr(
        g.edges
            .iter()
            .map(|&(i, j, w)| Json::arr_i32(&[i as i32, j as i32, -w]))
            .collect(),
    );
    let mut pairs = vec![
        ("type", Json::str("solve")),
        ("id", Json::num(id as f64)),
        ("n", Json::num(g.n as f64)),
        ("edges", edges),
        ("replicas", Json::num(replicas as f64)),
        ("max_periods", Json::num(max_periods as f64)),
        ("seed", Json::num(seed as f64)),
    ];
    if stream {
        pairs.push(("stream", Json::Bool(true)));
    }
    Json::obj(pairs).to_string()
}

/// Read lines until the solve *result* for `id` arrives (result lines
/// uniquely carry `"spins"`), returning it plus how many progress lines
/// for that id preceded it.  Progress lines for *other* ids are skipped
/// uncounted: the worker's last progress event can legally race behind
/// its own result through the two reply channels, so a previous solve's
/// tail may still be in flight.  Panics on an error line.
fn read_result(r: &mut BufReader<TcpStream>, id: usize) -> (Json, usize) {
    let mut progress = 0;
    loop {
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(!line.is_empty(), "connection closed before the result");
        let v = Json::parse(line.trim()).unwrap();
        assert!(v.get("error").is_none(), "{line}");
        if v.get("spins").is_some() {
            assert_eq!(v.get("id").and_then(Json::as_usize), Some(id), "{line}");
            return (v, progress);
        }
        assert_eq!(v.get("type").and_then(Json::as_str), Some("progress"), "{line}");
        if v.get("id").and_then(Json::as_usize) == Some(id) {
            progress += 1;
        }
    }
}

#[test]
fn serve_tcp_exits_on_shutdown_without_a_final_client() {
    // The regression this guards: the old accept loop blocked in
    // accept(2) after shutdown, so the serve thread only exited once
    // one more client happened to connect.  The fixed loop polls the
    // router's shutdown latch and must return on its own.
    let coord = Coordinator::start(vec![], BatchPolicy::default()).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let router = Arc::clone(&coord.router);
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        tx.send(serve_tcp(router, listener)).unwrap();
    });

    // Serve one real request first so the loop is demonstrably live.
    let g = Graph::complete_bipartite(3, 3);
    let stream = TcpStream::connect(addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    w.write_all(solve_line(1, &g, 8, 64, 9, false).as_bytes())
        .unwrap();
    w.write_all(b"\n").unwrap();
    let (_res, _) = read_result(&mut r, 1);

    coord.shutdown().unwrap();
    // No further client connects; the serve loop must still return.
    let exited = rx
        .recv_timeout(Duration::from_secs(5))
        .expect("serve_tcp never exited after shutdown");
    exited.expect("serve_tcp returned an error on clean shutdown");
}

#[test]
fn evented_disconnect_mid_solve_cancels_and_pool_stays_live() {
    let coord = Coordinator::start_with_solver(
        vec![],
        BatchPolicy::default(),
        SolverPoolConfig {
            workers: 1,
            pack_max_oscillators: 0,
            ..Default::default()
        },
    )
    .unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let router = Arc::clone(&coord.router);
    let serve = std::thread::spawn(move || serve_evented(router, listener));

    // A guaranteed-long anneal: a constant schedule holds its noise
    // level through the whole noisy prefix, and the portfolio's
    // plateau / all-settled early exits only fire at noise level 0 —
    // so this solve cannot finish early and is still running when the
    // client vanishes.
    let g = Graph::random(48, 0.3, &mut Rng::new(91));
    let mut line = solve_line(77, &g, 32, 32_768, 5, true);
    line = format!(
        "{},\"schedule\":\"constant\",\"noise\":0.9}}",
        &line[..line.len() - 1]
    );
    let stream = TcpStream::connect(addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream.try_clone().unwrap());
    w.write_all(line.as_bytes()).unwrap();
    w.write_all(b"\n").unwrap();

    // The first progress line proves the anneal is running mid-flight.
    let mut first = String::new();
    r.read_line(&mut first).unwrap();
    let v = Json::parse(first.trim()).unwrap();
    assert_eq!(v.get("type").and_then(Json::as_str), Some("progress"), "{first}");
    assert_eq!(v.get("id").and_then(Json::as_usize), Some(77));
    assert!(v.get("best_energy").is_some(), "{first}");

    // Disconnect.  The reap sweep must set the job's cancel flag and
    // the worker must abandon the anneal at the next chunk boundary.
    drop(r);
    drop(w);
    drop(stream);
    let deadline = Instant::now() + Duration::from_secs(30);
    while coord.snapshot().solves_cancelled == 0 {
        assert!(Instant::now() < deadline, "disconnect never cancelled the in-flight solve");
        std::thread::sleep(Duration::from_millis(20));
    }

    // The single worker is free again: a fresh client's solve completes.
    let g2 = Graph::complete_bipartite(3, 3);
    let stream = TcpStream::connect(addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    w.write_all(solve_line(78, &g2, 8, 64, 9, false).as_bytes())
        .unwrap();
    w.write_all(b"\n").unwrap();
    let (res, _) = read_result(&mut r, 78);
    let spins: Vec<i8> = res
        .get("spins")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|x| x.as_i64().unwrap() as i8)
        .collect();
    assert_eq!(g2.cut_value(&spins), 9);

    let snap = coord.snapshot();
    assert_eq!(snap.solves_cancelled, 1);
    assert_eq!(snap.solves_completed, 1);
    assert_eq!(snap.solves_failed, 0, "a cancel is not a failure");

    coord.shutdown().unwrap();
    serve
        .join()
        .expect("serve thread panicked")
        .expect("serve_evented returned an error on clean shutdown");
}

#[test]
fn streaming_solve_emits_progress_then_the_result() {
    // A streaming solve over the evented front end interleaves
    // monotone progress lines before the result; a non-streaming solve
    // on the same connection gets only its result.
    let coord = Coordinator::start(vec![], BatchPolicy::default()).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let router = Arc::clone(&coord.router);
    let serve = std::thread::spawn(move || serve_evented(router, listener));

    let g = Graph::random(24, 0.25, &mut Rng::new(17));
    let stream = TcpStream::connect(addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);

    w.write_all(solve_line(5, &g, 8, 256, 3, true).as_bytes())
        .unwrap();
    w.write_all(b"\n").unwrap();
    let (_res, progress) = read_result(&mut r, 5);
    assert!(
        progress >= 1,
        "a streaming 256-period solve must emit progress lines"
    );

    w.write_all(solve_line(6, &g, 8, 256, 3, false).as_bytes())
        .unwrap();
    w.write_all(b"\n").unwrap();
    let (_res, progress) = read_result(&mut r, 6);
    assert_eq!(progress, 0, "stream defaults off: no progress lines");

    coord.shutdown().unwrap();
    serve.join().unwrap().unwrap();
}

/// Drive one line through a fresh single-worker pool `hits + 1` times
/// and return every response: request 0 builds cold (arena miss), each
/// repeat reprograms the parked engine (arena hit).
fn serve_repeatedly(cfg: SolverPoolConfig, line: &str, repeats: usize) -> Vec<String> {
    let coord = Coordinator::start_with_solver(vec![], BatchPolicy::default(), cfg).unwrap();
    let responses: Vec<String> = (0..repeats)
        .map(|_| handle_line(&coord.router, line))
        .collect();
    let snap = coord.snapshot();
    if cfg.arena_capacity > 0 {
        assert_eq!(snap.arena_misses, 1, "only the first build is cold");
        assert_eq!(snap.arena_hits as usize, repeats - 1);
    } else {
        assert_eq!(snap.arena_hits, 0, "capacity 0 must never warm");
        assert_eq!(snap.arena_evictions as usize, repeats);
    }
    coord.shutdown().unwrap();
    responses
}

#[test]
fn arena_hit_solve_is_byte_identical_to_cold_on_every_fabric() {
    // The arena's load-bearing contract (DESIGN_SOLVER.md §10): a solve
    // served by a reprogrammed warm engine answers byte-for-byte like a
    // cold build at equal seed — on the native, sharded, and rtl
    // fabrics.  Packing is disabled so every request takes the solo
    // checkout path; one worker so both requests share one arena.
    let base = SolverPoolConfig {
        workers: 1,
        pack_max_oscillators: 0,
        ..Default::default()
    };
    let cases: [(&str, SolverPoolConfig, Graph, usize, usize); 3] = [
        ("native", base, Graph::random(18, 0.3, &mut Rng::new(55)), 6, 64),
        (
            "sharded",
            SolverPoolConfig {
                shard_threshold: 12,
                max_shards: 3,
                ..base
            },
            Graph::random(18, 0.3, &mut Rng::new(55)),
            6,
            64,
        ),
        (
            "rtl",
            SolverPoolConfig { rtl: true, ..base },
            Graph::random(10, 0.4, &mut Rng::new(77)),
            4,
            32,
        ),
    ];
    for (engine, cfg, g, replicas, periods) in cases {
        let line = solve_line(900, &g, replicas, periods, 12, false);
        let warm = serve_repeatedly(cfg, &line, 3);
        let cold = serve_repeatedly(
            SolverPoolConfig {
                arena_capacity: 0,
                ..cfg
            },
            &line,
            1,
        );
        let v = Json::parse(&warm[0]).unwrap();
        assert!(v.get("error").is_none(), "{engine}: {}", warm[0]);
        assert_eq!(
            v.get("engine").and_then(Json::as_str),
            Some(engine),
            "{engine}: wrong fabric served the request"
        );
        assert_eq!(warm[0], warm[1], "{engine}: first arena hit diverged from the cold build");
        assert_eq!(warm[1], warm[2], "{engine}: repeated arena hits diverged");
        assert_eq!(warm[0], cold[0], "{engine}: warm pool diverged from the no-arena pool");
    }
}

#[test]
fn malformed_flood_on_one_connection_does_not_stall_others() {
    let coord = Coordinator::start(vec![], BatchPolicy::default()).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let router = Arc::clone(&coord.router);
    let serve = std::thread::spawn(move || serve_evented(router, listener));

    let flood = TcpStream::connect(addr).unwrap();
    let mut fw = flood.try_clone().unwrap();
    let good = TcpStream::connect(addr).unwrap();
    let mut gw = good.try_clone().unwrap();
    let mut gr = BufReader::new(good);

    // One connection spews garbage while the other asks for a real
    // solve: per-connection buffering means the good client's line is
    // dispatched and answered regardless.
    for _ in 0..200 {
        fw.write_all(b"this is not json\n").unwrap();
    }
    let g = Graph::complete_bipartite(3, 3);
    gw.write_all(solve_line(42, &g, 8, 64, 9, false).as_bytes())
        .unwrap();
    gw.write_all(b"\n").unwrap();
    let (res, _) = read_result(&mut gr, 42);
    let spins: Vec<i8> = res
        .get("spins")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|x| x.as_i64().unwrap() as i8)
        .collect();
    assert_eq!(g.cut_value(&spins), 9);

    // The flooder is answered too — one error line per garbage line,
    // not silence and not a dropped connection.
    let mut fr = BufReader::new(flood);
    for i in 0..200 {
        let mut e = String::new();
        fr.read_line(&mut e).unwrap();
        assert!(e.contains("\"error\""), "flood line {i}: {e}");
    }

    coord.shutdown().unwrap();
    serve.join().unwrap().unwrap();
}
