//! Property obligations of the RTL solver-engine refactor (ISSUE 4):
//!
//! (a) The chunked, batch-lane hybrid stepper (`runtime::rtl::RtlEngine`
//!     over the multi-lane `HybridOnn`) is **tick-for-tick identical**
//!     to the pre-refactor run-to-completion simulator.  Oracles:
//!     `RecurrentOnn` — untouched by the refactor and structurally the
//!     synchronized hybrid's per-tick dynamics (the paper's Table 6
//!     finding, pinned by `synchronized_hybrid_identical_to_recurrent`)
//!     — for the trajectory, and `HybridOnn::run_to_settle` (the
//!     monolithic driver) for the settle index.
//!
//! (b) An `RtlEngine` solve is **deterministic at equal seed**
//!     end-to-end: through `solver::portfolio::solve_with`, and through
//!     the coordinator's TCP JSON-lines path on an rtl-configured
//!     solver pool.

use std::sync::Arc;

use onn_scale::coordinator::batcher::BatchPolicy;
use onn_scale::coordinator::server::{handle_line, serve_tcp, Coordinator, SolverPoolConfig};
use onn_scale::onn::config::NetworkConfig;
use onn_scale::onn::weights::WeightMatrix;
use onn_scale::rtl::hybrid::HybridOnn;
use onn_scale::rtl::recurrent::RecurrentOnn;
use onn_scale::rtl::RtlSim;
use onn_scale::runtime::rtl::RtlEngine;
use onn_scale::runtime::ChunkEngine;
use onn_scale::solver::graph::Graph;
use onn_scale::solver::portfolio::{solve_with, EngineSelect, PortfolioParams};
use onn_scale::solver::reductions::max_cut;
use onn_scale::util::json::Json;
use onn_scale::util::rng::Rng;

fn symmetric_weights(rng: &mut Rng, n: usize) -> WeightMatrix {
    let mut w = WeightMatrix::zeros(n);
    for i in 0..n {
        for j in 0..=i {
            let v = rng.range_i64(-8, 9) as i8;
            w.set(i, j, v);
            w.set(j, i, v);
        }
    }
    w
}

#[test]
fn chunked_lanes_match_the_pre_refactor_trajectory_tick_for_tick() {
    let mut rng = Rng::new(7001);
    for &n in &[5usize, 9] {
        let cfg = NetworkConfig::paper(n);
        let w = symmetric_weights(&mut rng, n);
        for chunk in [1usize, 3, 8] {
            let batch = 2usize;
            let total_periods = 24usize;
            let mut engine = RtlEngine::new(cfg, batch, chunk);
            engine.set_weights(&w.to_f32()).unwrap();
            let inits: Vec<Vec<i32>> = (0..batch)
                .map(|_| (0..n).map(|_| rng.range_i64(0, 16) as i32).collect())
                .collect();
            let mut phases: Vec<i32> = inits.concat();
            let mut settled = vec![-1i32; batch];
            // Per-lane oracles, ticked by hand: the recurrent design
            // (pre-refactor reference dynamics) and a monolithic hybrid
            // driven through the classic single-trial RtlSim interface.
            let mut ra_oracles: Vec<RecurrentOnn> = inits
                .iter()
                .map(|init| {
                    let mut ra = RecurrentOnn::new(cfg, w.clone());
                    ra.set_phases(init);
                    ra
                })
                .collect();
            let mut ha_oracles: Vec<HybridOnn> = inits
                .iter()
                .map(|init| {
                    let mut ha = HybridOnn::new(cfg, w.clone());
                    ha.set_phases(init);
                    ha
                })
                .collect();
            for chunk_idx in 0..total_periods / chunk {
                engine
                    .run_chunk(&mut phases, &mut settled, (chunk_idx * chunk) as i32)
                    .unwrap();
                for lane in 0..batch {
                    for _ in 0..chunk * 16 {
                        ra_oracles[lane].tick();
                        ha_oracles[lane].tick();
                    }
                    assert_eq!(
                        &phases[lane * n..(lane + 1) * n],
                        ra_oracles[lane].phases(),
                        "n={n} chunk_len={chunk} lane={lane} chunk={chunk_idx}: \
                         diverged from the recurrent oracle"
                    );
                    assert_eq!(
                        &phases[lane * n..(lane + 1) * n],
                        ha_oracles[lane].phases(),
                        "n={n} chunk_len={chunk} lane={lane} chunk={chunk_idx}: \
                         diverged from the monolithic hybrid"
                    );
                }
            }
            // The chunk-spanning settle flags must report exactly the
            // period index the monolithic run-to-completion driver does.
            for (lane, init) in inits.iter().enumerate() {
                let mut mono = HybridOnn::new(cfg, w.clone());
                mono.set_phases(init);
                let out = mono.run_to_settle(total_periods);
                match out.settled {
                    Some(k) => assert_eq!(
                        settled[lane], k as i32,
                        "n={n} chunk_len={chunk} lane={lane}: settle index"
                    ),
                    None => assert_eq!(
                        settled[lane], -1,
                        "n={n} chunk_len={chunk} lane={lane}: phantom settle"
                    ),
                }
            }
        }
    }
}

#[test]
fn rtl_solve_is_deterministic_through_solve_with() {
    let g = Graph::random(10, 0.35, &mut Rng::new(7100));
    let problem = max_cut(&g);
    let params = PortfolioParams {
        replicas: 4,
        max_periods: 32,
        seed: 4242,
        ..Default::default()
    };
    let a = solve_with(&problem, &params, EngineSelect::Rtl).unwrap();
    let b = solve_with(&problem, &params, EngineSelect::Rtl).unwrap();
    assert_eq!(a.engine, "rtl");
    assert!(a.noise_applied, "the rtl engine must support the noise hook");
    assert_eq!(a.best_energy, b.best_energy);
    assert_eq!(a.best_spins, b.best_spins);
    assert_eq!(a.best_phases, b.best_phases);
    assert_eq!(a.replica_phases, b.replica_phases);
    assert_eq!(a.periods, b.periods);
    assert_eq!(a.settled_replicas, b.settled_replicas);
    assert_eq!(a.quantization_error, b.quantization_error);
    let (ha, hb) = (a.hardware.unwrap(), b.hardware.unwrap());
    assert_eq!(ha, hb, "the emulated cost meter must be deterministic too");
    assert!(ha.fast_cycles > 0);
    // A different seed must explore differently — the noise hook is
    // actually wired, not silently ignored.
    let mut other = params;
    other.seed = 4243;
    let c = solve_with(&problem, &other, EngineSelect::Rtl).unwrap();
    assert_ne!(
        a.replica_phases, c.replica_phases,
        "different seeds produced identical trajectories"
    );
}

/// JSON-lines solve request for a graph with J = -1 couplings.
fn solve_line_json(id: u64, g: &Graph, replicas: usize, max_periods: usize, seed: u64) -> String {
    let edges = Json::Arr(
        g.edges
            .iter()
            .map(|&(i, j, w)| Json::arr_i32(&[i as i32, j as i32, -w]))
            .collect(),
    );
    Json::obj(vec![
        ("type", Json::str("solve")),
        ("id", Json::num(id as f64)),
        ("n", Json::num(g.n as f64)),
        ("edges", edges),
        ("replicas", Json::num(replicas as f64)),
        ("max_periods", Json::num(max_periods as f64)),
        ("seed", Json::num(seed as f64)),
    ])
    .to_string()
}

#[test]
fn rtl_solve_is_deterministic_over_tcp() {
    use std::io::{BufRead, BufReader, Write};
    let coord = Coordinator::start_with_solver(
        vec![],
        BatchPolicy::default(),
        SolverPoolConfig {
            workers: 1,
            rtl: true,
            ..Default::default()
        },
    )
    .unwrap();
    let g = Graph::random(8, 0.4, &mut Rng::new(7200));
    let line = solve_line_json(61, &g, 4, 32, 17);

    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let router = Arc::clone(&coord.router);
    std::thread::spawn(move || {
        let _ = serve_tcp(router, listener);
    });
    let stream = std::net::TcpStream::connect(addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    let mut responses = Vec::new();
    for _ in 0..2 {
        w.write_all(line.as_bytes()).unwrap();
        w.write_all(b"\n").unwrap();
        let mut resp = String::new();
        r.read_line(&mut resp).unwrap();
        responses.push(resp.trim().to_string());
    }
    assert_eq!(
        responses[0], responses[1],
        "equal seed must serve byte-identical rtl responses"
    );
    let v = Json::parse(&responses[0]).unwrap();
    assert!(v.get("error").is_none(), "{}", responses[0]);
    assert_eq!(v.get("engine").and_then(Json::as_str), Some("rtl"));
    assert_eq!(v.get("sync_rounds").and_then(Json::as_usize), Some(0));
    assert!(
        v.get("hw_fast_cycles").and_then(Json::as_usize).unwrap() > 0,
        "rtl responses must price the emulated hardware run"
    );
    assert!(v.get("hw_emulated_s").and_then(Json::as_f64).unwrap() > 0.0);
    assert_eq!(v.get("hw_fits_device").and_then(Json::as_bool), Some(true));
    assert!(v.get("quantization_error").and_then(Json::as_f64).is_some());

    // The in-process path of a second rtl pool serves the same bytes —
    // the whole stack is deterministic, not just one connection.
    let coord2 = Coordinator::start_with_solver(
        vec![],
        BatchPolicy::default(),
        SolverPoolConfig {
            workers: 1,
            rtl: true,
            ..Default::default()
        },
    )
    .unwrap();
    let inproc = handle_line(&coord2.router, &line);
    assert_eq!(inproc, responses[0]);

    // Metrics meter the rtl fast cycles.
    let snap = coord.snapshot();
    assert_eq!(snap.solves_completed, 2);
    assert_eq!(snap.solves_rtl, 2);
    assert!(snap.solve_fast_cycles > 0);
    assert_eq!(snap.solves_sharded, 0);

    coord.shutdown().unwrap();
    coord2.shutdown().unwrap();
}

#[test]
fn rtl_and_native_pools_share_the_wire_contract() {
    // The same request line served by an rtl pool and a native pool:
    // different dynamics, same wire shape — and both report the same
    // embedding quantization error (a property of the problem).
    let rtl_coord = Coordinator::start_with_solver(
        vec![],
        BatchPolicy::default(),
        SolverPoolConfig {
            workers: 1,
            rtl: true,
            ..Default::default()
        },
    )
    .unwrap();
    let native_coord = Coordinator::start(vec![], BatchPolicy::default()).unwrap();
    let g = Graph::random(9, 0.4, &mut Rng::new(7300));
    let line = solve_line_json(71, &g, 4, 32, 23);
    let rtl = Json::parse(&handle_line(&rtl_coord.router, &line)).unwrap();
    let native = Json::parse(&handle_line(&native_coord.router, &line)).unwrap();
    assert!(rtl.get("error").is_none(), "{rtl}");
    assert!(native.get("error").is_none(), "{native}");
    assert_eq!(rtl.get("engine").and_then(Json::as_str), Some("rtl"));
    assert_eq!(native.get("engine").and_then(Json::as_str), Some("native"));
    assert_eq!(
        rtl.get("quantization_error").and_then(Json::as_f64),
        native.get("quantization_error").and_then(Json::as_f64)
    );
    assert!(rtl.get("hw_fast_cycles").is_some());
    assert!(
        native.get("hw_fast_cycles").is_none(),
        "float fabrics have no hardware to meter"
    );
    rtl_coord.shutdown().unwrap();
    native_coord.shutdown().unwrap();
}
