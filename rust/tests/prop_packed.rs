//! Property tests for the packed multi-problem solve path: a lane-block
//! engine whose batch lanes carry *different* Ising problems must be
//! **bit-exact, lane by lane, with each problem solved solo** at the
//! same seed — energies, readout spins, phases, and period counts —
//! including lanes that retire early (per-lane plateau / all-settled
//! exit) while neighbors keep annealing, lanes that are backfilled
//! mid-run from the overflow queue, and lanes padded up to a larger
//! oscillator bucket.  This is the serving analog of the paper's
//! time-multiplexed coupling rows: sharing the fabric must not change
//! any problem's answer.

use onn_scale::onn::config::NetworkConfig;
use onn_scale::runtime::native::NativeEngine;
use onn_scale::runtime::sharded::ShardedEngine;
use onn_scale::runtime::ChunkEngine;
use onn_scale::solver::portfolio::{
    solve_packed, solve_packed_native, solve_with, EngineSelect, PortfolioParams, SolveOutcome,
};
use onn_scale::solver::problem::IsingProblem;
use onn_scale::solver::reductions::{coloring, max_cut, min_vertex_cover};
use onn_scale::solver::Graph;
use onn_scale::util::rng::Rng;

/// A random small instance: max-cut (binary), 3-coloring (sectors), or
/// vertex cover (fields -> ancilla embedding), with randomized replica
/// counts, budgets, and seeds.
fn random_entry(rng: &mut Rng, chunk: usize) -> (IsingProblem, PortfolioParams) {
    let n = 5 + rng.usize_below(10); // 5..=14 oscillators
    let g = Graph::random(n, 0.35, rng);
    let problem = match rng.usize_below(3) {
        0 => max_cut(&g),
        1 => coloring(&g, 3),
        _ => min_vertex_cover(&g, 2.0),
    };
    let params = PortfolioParams {
        replicas: 2 + rng.usize_below(4),             // 2..=5
        max_periods: chunk * (4 + rng.usize_below(6)), // 4..=9 chunks
        seed: rng.next_u64(),
        chunk,
        ..Default::default()
    };
    (problem, params)
}

fn bucket_of(entries: &[(IsingProblem, PortfolioParams)]) -> usize {
    entries
        .iter()
        .map(|(p, _)| p.embed_dim())
        .max()
        .unwrap()
        .next_power_of_two()
}

fn assert_bit_exact(case: &str, out: &SolveOutcome, solo: &SolveOutcome) {
    assert_eq!(out.best_energy, solo.best_energy, "{case}: energies differ");
    assert_eq!(out.best_spins, solo.best_spins, "{case}: spins differ");
    assert_eq!(out.best_phases, solo.best_phases, "{case}: phases differ");
    assert_eq!(out.periods, solo.periods, "{case}: period counts differ");
    assert_eq!(out.chunks, solo.chunks, "{case}: chunk counts differ");
    assert_eq!(
        out.settled_replicas, solo.settled_replicas,
        "{case}: settle counts differ"
    );
    assert_eq!(out.early_exit, solo.early_exit, "{case}: exit kinds differ");
    assert_eq!(
        out.replica_phases, solo.replica_phases,
        "{case}: replica readouts differ"
    );
    assert_eq!(
        out.initial_best_energy, solo.initial_best_energy,
        "{case}: initial bests differ"
    );
}

#[test]
fn prop_packed_mixes_bit_exact_with_solo_at_both_chunk_sizes() {
    // Random mixes of 2..=6 problems, all lanes resident at once, for
    // the default 8-period chunk AND a 4-period chunk (the geometry is
    // threaded from PortfolioParams since the solve_with fix).
    let mut rng = Rng::new(7001);
    for case in 0..6 {
        for chunk in [8usize, 4] {
            let count = 2 + rng.usize_below(5); // 2..=6 problems
            let entries: Vec<_> = (0..count).map(|_| random_entry(&mut rng, chunk)).collect();
            let lanes: usize = entries.iter().map(|(_, p)| p.replicas).sum();
            let bucket = bucket_of(&entries);
            let packed = solve_packed_native(bucket, lanes, chunk, &entries).unwrap();
            assert_eq!(packed.len(), count);
            for (i, ((problem, params), out)) in entries.iter().zip(&packed).enumerate() {
                let solo = solve_with(problem, params, EngineSelect::Native).unwrap();
                assert!(out.noise_applied, "packed lanes must anneal");
                assert_bit_exact(
                    &format!("case {case} chunk {chunk} entry {i}"),
                    out,
                    &solo,
                );
            }
        }
    }
}

#[test]
fn prop_packed_early_retirement_leaves_neighbors_untouched() {
    // A mix engineered so retirement order is wildly uneven: zero-J
    // problems (settle the moment noise stops) next to long-budget
    // frustrated instances.  Every lane must still match solo exactly.
    let mut rng = Rng::new(7002);
    for chunk in [8usize, 4] {
        let quick_a = (
            IsingProblem::new(6),
            PortfolioParams {
                replicas: 3,
                max_periods: chunk * 12,
                seed: 901,
                chunk,
                ..Default::default()
            },
        );
        let slow = {
            let g = Graph::random(12, 0.5, &mut rng);
            (
                max_cut(&g),
                PortfolioParams {
                    replicas: 5,
                    // Twice the quick lanes' budget: its noise-free tail
                    // (the earliest any exit can fire under a geometric
                    // schedule) starts after the quick lanes are gone.
                    max_periods: chunk * 24,
                    seed: 902,
                    plateau_chunks: 0, // only the budget or all-settled stops it
                    chunk,
                    ..Default::default()
                },
            )
        };
        let quick_b = (
            IsingProblem::new(9),
            PortfolioParams {
                replicas: 2,
                max_periods: chunk * 12,
                seed: 903,
                chunk,
                ..Default::default()
            },
        );
        let entries = vec![quick_a, slow, quick_b];
        let lanes: usize = entries.iter().map(|(_, p)| p.replicas).sum();
        let packed = solve_packed_native(16, lanes, chunk, &entries).unwrap();
        let solos: Vec<_> = entries
            .iter()
            .map(|(p, prm)| solve_with(p, prm, EngineSelect::Native).unwrap())
            .collect();
        // The zero-J problems must actually retire before the budget...
        assert!(packed[0].early_exit, "zero-J lane should exit early");
        assert!(packed[2].early_exit, "zero-J lane should exit early");
        // ...and run strictly fewer chunks than the long-budget lane.
        assert!(packed[0].chunks < packed[1].chunks, "chunk {chunk}");
        for (i, (out, solo)) in packed.iter().zip(&solos).enumerate() {
            assert_bit_exact(&format!("uneven chunk {chunk} entry {i}"), out, solo);
        }
    }
}

#[test]
fn prop_packed_backfill_matches_solo() {
    // More problems than the engine has lanes: the overflow waits in
    // the queue and backfills lanes as earlier blocks retire.  Every
    // problem — resident or backfilled — must match its solo run.
    let mut rng = Rng::new(7003);
    for case in 0..3 {
        let chunk = 8;
        let entries: Vec<_> = (0..5).map(|_| random_entry(&mut rng, chunk)).collect();
        let max_block = entries.iter().map(|(_, p)| p.replicas).max().unwrap();
        let total: usize = entries.iter().map(|(_, p)| p.replicas).sum();
        // Capacity for roughly half the mix forces real backfill.
        let lanes = max_block.max(total / 2);
        let bucket = bucket_of(&entries);
        let packed = solve_packed_native(bucket, lanes, chunk, &entries).unwrap();
        for (i, ((problem, params), out)) in entries.iter().zip(&packed).enumerate() {
            let solo = solve_with(problem, params, EngineSelect::Native).unwrap();
            assert_bit_exact(&format!("backfill case {case} entry {i}"), out, &solo);
        }
    }
}

#[test]
fn prop_packed_on_the_sharded_fabric_matches_native_packing() {
    // Lane blocks exist on both fabrics; a packed mix on the row-sharded
    // cluster must equal the native packed run (and hence solo runs).
    let mut rng = Rng::new(7004);
    let chunk = 8;
    let entries: Vec<_> = (0..3).map(|_| random_entry(&mut rng, chunk)).collect();
    let lanes: usize = entries.iter().map(|(_, p)| p.replicas).sum();
    let bucket = bucket_of(&entries);
    let native = solve_packed_native(bucket, lanes, chunk, &entries).unwrap();
    let mut cluster =
        ShardedEngine::unprogrammed(NetworkConfig::paper(bucket), 3, lanes, chunk).unwrap();
    let sharded = solve_packed(&mut cluster, &entries).unwrap();
    for (i, (a, b)) in native.iter().zip(&sharded).enumerate() {
        assert_eq!(a.best_energy, b.best_energy, "entry {i}");
        assert_eq!(a.best_spins, b.best_spins, "entry {i}");
        assert_eq!(a.best_phases, b.best_phases, "entry {i}");
        assert_eq!(a.periods, b.periods, "entry {i}");
        assert_eq!(a.settled_replicas, b.settled_replicas, "entry {i}");
    }
    assert!(sharded.iter().all(|o| o.engine == "sharded"));
    // Each problem is billed only its own share of the fabric's
    // all-gather rounds: one per period per lane, exactly what a solo
    // sharded run of that problem would pay.
    for o in &sharded {
        assert_eq!(o.sync_rounds, (o.replicas * o.periods) as u64);
    }
    cluster.shutdown();
}

#[test]
fn regression_reprogrammed_block_restarts_the_kick_stream() {
    // The backfill regression: a lane block that is cleared and then
    // re-programmed (what backfilling a retired lane does) must start a
    // FRESH noise stream, not resume the retired problem's tick counter.
    // Zero couplings isolate the kick stream: any phase motion is noise.
    let cfg = NetworkConfig::paper(6);
    let w = vec![0.0f32; 36];
    let init: Vec<i32> = vec![1, 5, 9, 2, 6, 10, 3, 7, 11, 4, 8, 12];
    let run_fresh = || {
        let mut e = NativeEngine::new(cfg, 2, 4);
        e.set_lane_block(0, 2, &w).unwrap();
        e.set_lane_block_noise(0, 0.9, 7).unwrap();
        let mut ph = init.clone();
        let mut st = vec![-1i32; 2];
        e.run_chunk(&mut ph, &mut st, 0).unwrap();
        ph
    };
    let fresh = run_fresh();
    assert_ne!(fresh, init, "amplitude 0.9 must move zero-J phases");

    let mut e = NativeEngine::new(cfg, 2, 4);
    e.set_lane_block(0, 2, &w).unwrap();
    e.set_lane_block_noise(0, 0.9, 7).unwrap();
    let mut ph = init.clone();
    let mut st = vec![-1i32; 2];
    e.run_chunk(&mut ph, &mut st, 0).unwrap();
    assert_eq!(ph, fresh, "first chunk replays the fresh stream");
    // Sensitivity check: WITHOUT re-programming, the stream continues —
    // a second chunk from the same start must differ from the first
    // (ticks 8.. instead of 0..), so the assertion below has teeth.
    let mut ph2 = init.clone();
    let mut st2 = vec![-1i32; 2];
    e.run_chunk(&mut ph2, &mut st2, 4).unwrap();
    assert_ne!(ph2, fresh, "tick counter must advance within a block");
    // Retire + backfill the same lanes: the stream must restart.
    e.clear_lane_block(0).unwrap();
    e.set_lane_block(0, 2, &w).unwrap();
    e.set_lane_block_noise(0, 0.9, 7).unwrap();
    let mut ph3 = init.clone();
    let mut st3 = vec![-1i32; 2];
    e.run_chunk(&mut ph3, &mut st3, 0).unwrap();
    assert_eq!(
        ph3, fresh,
        "backfilled block inherited the retired lane's tick counter"
    );
    // Same regression on the sharded fabric.
    let mut sh = ShardedEngine::unprogrammed(cfg, 2, 2, 4).unwrap();
    sh.set_lane_block(0, 2, &w).unwrap();
    sh.set_lane_block_noise(0, 0.9, 7).unwrap();
    let mut pha = init.clone();
    let mut sta = vec![-1i32; 2];
    sh.run_chunk(&mut pha, &mut sta, 0).unwrap();
    sh.clear_lane_block(0).unwrap();
    sh.set_lane_block(0, 2, &w).unwrap();
    sh.set_lane_block_noise(0, 0.9, 7).unwrap();
    let mut phb = init.clone();
    let mut stb = vec![-1i32; 2];
    sh.run_chunk(&mut phb, &mut stb, 0).unwrap();
    assert_eq!(phb, fresh, "sharded backfill must also restart the stream");
    sh.shutdown();
}

#[test]
fn regression_reprogramming_weights_alone_drops_stale_noise() {
    // set_lane_block (without clear) is also a backfill path: replacing
    // a block's weights must discard the old noise stream entirely —
    // until fresh noise is installed, the block runs deterministically.
    let cfg = NetworkConfig::paper(5);
    let w = vec![0.0f32; 25];
    let init = vec![3i32, 7, 11, 1, 9];
    let mut e = NativeEngine::new(cfg, 1, 4);
    e.set_lane_block(0, 1, &w).unwrap();
    e.set_lane_block_noise(0, 1.0, 13).unwrap();
    let mut ph = init.clone();
    let mut st = vec![-1i32; 1];
    e.run_chunk(&mut ph, &mut st, 0).unwrap();
    assert_ne!(ph, init, "noise was live");
    e.set_lane_block(0, 1, &w).unwrap(); // reprogram, no explicit clear
    let mut ph2 = init.clone();
    let mut st2 = vec![-1i32; 1];
    e.run_chunk(&mut ph2, &mut st2, 0).unwrap();
    assert_eq!(ph2, init, "stale noise leaked into the reprogrammed block");
}
