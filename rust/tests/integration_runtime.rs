//! AOT artifact integration: the PJRT engine (HLO text lowered from the
//! JAX/Pallas model) must be bit-exact with the native engine, chunk
//! after chunk, for every lowered network size.
//!
//! Requires `make artifacts`; tests skip politely when artifacts are
//! missing so `cargo test` works in a fresh checkout.  The whole suite
//! is gated on the `pjrt` build feature (the default offline build has
//! no PJRT engine to cross-validate).

#![cfg(feature = "pjrt")]

use onn_scale::harness::datasets::benchmark_by_name;
use onn_scale::onn::config::NetworkConfig;
use onn_scale::runtime::artifact::{default_dir, Manifest};
use onn_scale::runtime::engine::{run_to_settle_batch, PjrtContext, PjrtEngine};
use onn_scale::runtime::native::NativeEngine;
use onn_scale::runtime::ChunkEngine;
use onn_scale::util::rng::Rng;

fn manifest_or_skip() -> Option<Manifest> {
    match Manifest::load(&default_dir()) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP: no artifacts ({e:#}); run `make artifacts`");
            None
        }
    }
}

fn rand_w(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n * n).map(|_| rng.range_i64(-16, 16) as f32).collect()
}

#[test]
fn pjrt_bit_exact_with_native_random_weights() {
    let Some(manifest) = manifest_or_skip() else {
        return;
    };
    let ctx = PjrtContext::cpu().expect("pjrt client");
    let mut rng = Rng::new(42);
    // Small sizes keep this test fast; larger sizes are covered by the
    // crosscheck CLI and the benches.
    for n in [8, 9, 20, 42] {
        let Some(info) = manifest.chunk_for(n) else {
            continue;
        };
        let mut pjrt = PjrtEngine::load(ctx.clone(), info).expect("load artifact");
        let mut native = NativeEngine::new(NetworkConfig::paper(n), info.batch, info.chunk);
        let w = rand_w(&mut rng, n);
        pjrt.set_weights(&w).unwrap();
        native.set_weights(&w).unwrap();
        let b = info.batch;
        let init: Vec<i32> = (0..b * n).map(|_| rng.range_i64(0, 16) as i32).collect();
        let (mut pa, mut pb) = (init.clone(), init);
        let (mut sa, mut sb) = (vec![-1i32; b], vec![-1i32; b]);
        for k in 0..3 {
            let p0 = (k * info.chunk) as i32;
            pjrt.run_chunk(&mut pa, &mut sa, p0).unwrap();
            native.run_chunk(&mut pb, &mut sb, p0).unwrap();
            assert_eq!(pa, pb, "phases diverged at n={n} chunk {k}");
            assert_eq!(sa, sb, "settled diverged at n={n} chunk {k}");
        }
    }
}

#[test]
fn pjrt_retrieves_trained_patterns() {
    let Some(manifest) = manifest_or_skip() else {
        return;
    };
    let set = benchmark_by_name("7x6").unwrap();
    let Some(info) = manifest.chunk_for(set.cfg.n) else {
        eprintln!("SKIP: no artifact for n={}", set.cfg.n);
        return;
    };
    let ctx = PjrtContext::cpu().expect("pjrt client");
    let mut eng = PjrtEngine::load(ctx, info).expect("load");
    eng.set_weights(&set.weights.to_f32()).unwrap();

    use onn_scale::onn::phase::{spin_to_phase, state_to_spins};
    let p = set.cfg.period() as i32;
    let b = info.batch;
    let n = set.cfg.n;
    let mut rng = Rng::new(9);
    // One batch of corruptions of pattern 0.
    let target = &set.dataset.patterns[0];
    let mut phases = Vec::with_capacity(b * n);
    for _ in 0..b {
        let corrupted = target.corrupt(target.corruption_count(10.0), &mut rng);
        phases.extend(corrupted.spins.iter().map(|&s| spin_to_phase(s, p)));
    }
    let settled = run_to_settle_batch(&mut eng, &mut phases, 256).unwrap();
    let mut correct = 0;
    for bi in 0..b {
        let spins = state_to_spins(&phases[bi * n..(bi + 1) * n], p);
        if settled[bi].is_some() && target.matches_up_to_inversion(&spins) {
            correct += 1;
        }
    }
    assert!(
        correct * 10 >= b * 9,
        "pjrt retrieval accuracy too low: {correct}/{b}"
    );
}

#[test]
fn settled_flags_sticky_across_chunks() {
    let Some(manifest) = manifest_or_skip() else {
        return;
    };
    let Some(info) = manifest.chunk_for(9) else {
        return;
    };
    let set = benchmark_by_name("3x3").unwrap();
    let ctx = PjrtContext::cpu().expect("pjrt client");
    let mut eng = PjrtEngine::load(ctx, info).expect("load");
    eng.set_weights(&set.weights.to_f32()).unwrap();

    use onn_scale::onn::phase::spin_to_phase;
    let (b, n) = (info.batch, 9);
    let p = set.cfg.period() as i32;
    // Start exactly on stored patterns: settle at period 0 and stay.
    let mut phases = Vec::new();
    for bi in 0..b {
        let pat = &set.dataset.patterns[bi % 2];
        phases.extend(pat.spins.iter().map(|&s| spin_to_phase(s, p)));
    }
    let snapshot = phases.clone();
    let mut settled = vec![-1i32; b];
    eng.run_chunk(&mut phases, &mut settled, 0).unwrap();
    assert!(settled.iter().all(|&s| s == 0), "{settled:?}");
    assert_eq!(phases, snapshot, "fixed points moved");
    let first = settled.clone();
    eng.run_chunk(&mut phases, &mut settled, info.chunk as i32)
        .unwrap();
    assert_eq!(settled, first, "settle periods must be sticky");
    assert_eq!(phases, snapshot);
}

#[test]
fn engine_rejects_malformed_inputs() {
    let Some(manifest) = manifest_or_skip() else {
        return;
    };
    let Some(info) = manifest.chunk_for(8) else {
        return;
    };
    let ctx = PjrtContext::cpu().expect("pjrt client");
    let mut eng = PjrtEngine::load(ctx, info).expect("load");
    assert!(eng.set_weights(&vec![0.0; 3]).is_err());
    eng.set_weights(&vec![0.0; 64]).unwrap();
    let mut bad_phases = vec![0i32; 7];
    let mut settled = vec![-1i32; info.batch];
    assert!(eng.run_chunk(&mut bad_phases, &mut settled, 0).is_err());
}
