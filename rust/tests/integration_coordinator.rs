//! Coordinator integration: routing, dynamic batching, concurrency,
//! metrics, and the TCP JSON-lines front-end, on native engine pools.

use std::sync::Arc;
use std::time::Duration;

use onn_scale::coordinator::batcher::BatchPolicy;
use onn_scale::coordinator::job::RetrievalRequest;
use onn_scale::coordinator::server::{handle_line, serve_tcp, Coordinator, EngineKind, PoolSpec};
use onn_scale::harness::datasets::benchmark_by_name;
use onn_scale::onn::phase::{spin_to_phase, state_to_spins};
use onn_scale::util::json::Json;
use onn_scale::util::rng::Rng;

fn start_3x3(max_wait_ms: u64) -> (Coordinator, onn_scale::harness::datasets::BenchmarkSet) {
    let set = benchmark_by_name("3x3").unwrap();
    let coord = Coordinator::start(
        vec![PoolSpec::new(set.cfg, set.weights.clone(), EngineKind::Native)],
        BatchPolicy {
            max_wait: Duration::from_millis(max_wait_ms),
            max_periods_cap: 256,
        },
    )
    .unwrap();
    (coord, set)
}

#[test]
fn retrieves_through_full_service_stack() {
    let (coord, set) = start_3x3(1);
    let p = set.cfg.period() as i32;
    let mut rng = Rng::new(1);
    for target in &set.dataset.patterns {
        let corrupted = target.corrupt(1, &mut rng);
        let req = RetrievalRequest::from_pattern(coord.next_id(), &corrupted, p, 256);
        let res = coord.retrieve_sync(req).unwrap();
        assert!(res.settled.is_some());
        assert!(target.matches_up_to_inversion(&state_to_spins(&res.phases, p)));
        assert!(res.total_latency >= res.queue_latency);
    }
    let snap = coord.snapshot();
    assert_eq!(snap.completed, 2);
    assert_eq!(snap.timeouts, 0);
    coord.shutdown().unwrap();
}

#[test]
fn concurrent_submitters_fill_batches() {
    let (coord, set) = start_3x3(20);
    let coord = Arc::new(coord);
    let p = set.cfg.period() as i32;
    let total = 64usize;
    let handles: Vec<_> = (0..total)
        .map(|i| {
            let coord = Arc::clone(&coord);
            let set = set.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(100 + i as u64);
                let target = &set.dataset.patterns[i % 2];
                let corrupted = target.corrupt(1, &mut rng);
                let req =
                    RetrievalRequest::from_pattern(i as u64, &corrupted, p, 256);
                let res = coord.retrieve_sync(req).unwrap();
                (res.settled.is_some(), res.batch_occupancy)
            })
        })
        .collect();
    let results: Vec<(bool, usize)> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert!(results.iter().all(|(ok, _)| *ok));
    let snap = coord.snapshot();
    assert_eq!(snap.completed, total as u64);
    // Dynamic batching must have packed multiple jobs per batch.
    assert!(
        snap.mean_occupancy > 1.5,
        "batcher never batched: occupancy {}",
        snap.mean_occupancy
    );
    assert!(snap.batches < total as u64, "one batch per job = no batching");
    Arc::try_unwrap(coord)
        .map_err(|_| ())
        .unwrap()
        .shutdown()
        .unwrap();
}

#[test]
fn multi_pool_routing() {
    let set3 = benchmark_by_name("3x3").unwrap();
    let set5 = benchmark_by_name("5x4").unwrap();
    let coord = Coordinator::start(
        vec![
            PoolSpec::new(set3.cfg, set3.weights.clone(), EngineKind::Native),
            PoolSpec::new(set5.cfg, set5.weights.clone(), EngineKind::Native),
        ],
        BatchPolicy::default(),
    )
    .unwrap();
    assert_eq!(coord.router.routes(), vec![9, 20]);
    let p = 16;
    let mut rng = Rng::new(2);
    // one job to each pool
    for set in [&set3, &set5] {
        let target = &set.dataset.patterns[0];
        let corrupted = target.corrupt(1, &mut rng);
        let req = RetrievalRequest::from_pattern(coord.next_id(), &corrupted, p, 256);
        let res = coord.retrieve_sync(req).unwrap();
        assert_eq!(res.phases.len(), set.cfg.n);
    }
    // unknown size rejected
    let bad = RetrievalRequest {
        id: 99,
        n: 77,
        phases: vec![0; 77],
        max_periods: 8,
    };
    assert!(coord.router.submit(bad).is_err());
    coord.shutdown().unwrap();
}

#[test]
fn handle_line_roundtrip_json() {
    let (coord, set) = start_3x3(1);
    let target = &set.dataset.patterns[0];
    let phases: Vec<i32> = target.spins.iter().map(|&s| spin_to_phase(s, 16)).collect();
    let req = Json::obj(vec![
        ("id", Json::num(5.0)),
        ("n", Json::num(9.0)),
        ("phases", Json::arr_i32(&phases)),
    ])
    .to_string();
    let resp = handle_line(&coord.router, &req);
    let v = Json::parse(&resp).unwrap();
    assert_eq!(v.get("id").and_then(Json::as_usize), Some(5));
    assert_eq!(
        v.get("settled").and_then(Json::as_usize),
        Some(0),
        "stored pattern settles immediately: {resp}"
    );
    assert_eq!(v.get("phases").and_then(Json::as_arr).map(|a| a.len()), Some(9));
    coord.shutdown().unwrap();
}

#[test]
fn tcp_server_serves_multiple_clients() {
    use std::io::{BufRead, BufReader, Write};
    let (coord, set) = start_3x3(1);
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let router = Arc::clone(&coord.router);
    std::thread::spawn(move || {
        let _ = serve_tcp(router, listener);
    });

    let clients: Vec<_> = (0..3)
        .map(|c| {
            let set = set.clone();
            std::thread::spawn(move || {
                let stream = std::net::TcpStream::connect(addr).unwrap();
                let mut w = stream.try_clone().unwrap();
                let mut r = BufReader::new(stream);
                let target = &set.dataset.patterns[c % 2];
                let phases: Vec<i32> = target
                    .spins
                    .iter()
                    .map(|&s| spin_to_phase(s, 16))
                    .collect();
                let req = Json::obj(vec![
                    ("id", Json::num(c as f64)),
                    ("n", Json::num(9.0)),
                    ("phases", Json::arr_i32(&phases)),
                ]);
                w.write_all(req.to_string().as_bytes()).unwrap();
                w.write_all(b"\n").unwrap();
                let mut line = String::new();
                r.read_line(&mut line).unwrap();
                let v = Json::parse(line.trim()).unwrap();
                assert!(v.get("error").is_none(), "{line}");
                v.get("settled").and_then(Json::as_usize)
            })
        })
        .collect();
    for c in clients {
        assert_eq!(c.join().unwrap(), Some(0));
    }
    coord.shutdown().unwrap();
}

#[test]
fn timeout_reported_not_hung() {
    // A 2-oscillator pure-cross network 2-cycles forever; the service
    // must report a timeout, not hang.
    use onn_scale::onn::config::NetworkConfig;
    use onn_scale::onn::weights::WeightMatrix;
    let mut w = WeightMatrix::zeros(2);
    w.set(0, 1, 8);
    w.set(1, 0, 8);
    let coord = Coordinator::start(
        vec![PoolSpec::new(NetworkConfig::paper(2), w, EngineKind::Native)],
        BatchPolicy {
            max_wait: Duration::from_millis(1),
            max_periods_cap: 64,
        },
    )
    .unwrap();
    let req = RetrievalRequest {
        id: 1,
        n: 2,
        phases: vec![0, 5],
        max_periods: 64,
    };
    let res = coord.retrieve_sync(req).unwrap();
    assert_eq!(res.settled, None);
    assert_eq!(coord.snapshot().timeouts, 1);
    coord.shutdown().unwrap();
}
