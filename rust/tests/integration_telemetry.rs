//! Observability integration: the solve-lifecycle trace contract
//! (bit-identical traced runs, monotone convergence), the wire trace
//! attachment, and the `{"type": "metrics"}` command scraped from a
//! live TCP pool after mixed native/sharded/rtl traffic
//! (DESIGN_SOLVER.md §9).

use std::sync::Arc;

use onn_scale::coordinator::batcher::BatchPolicy;
use onn_scale::coordinator::server::{handle_line, serve_tcp, Coordinator};
use onn_scale::solver::graph::Graph;
use onn_scale::solver::portfolio::{solve_native, solve_with_trace, EngineSelect, PortfolioParams};
use onn_scale::solver::reductions;
use onn_scale::telemetry::{sink, validate_trace_jsonl, TraceEvent, TraceSink, DEFAULT_TRACE_CAP};
use onn_scale::util::json::Json;
use onn_scale::util::rng::Rng;

fn params(replicas: usize, max_periods: usize, seed: u64) -> PortfolioParams {
    PortfolioParams {
        replicas,
        max_periods,
        seed,
        ..Default::default()
    }
}

/// JSON-lines solve request with optional engine/trace overrides.
fn solve_line(id: u64, g: &Graph, seed: u64, extra: &[(&str, Json)]) -> String {
    let edges = Json::Arr(
        g.edges
            .iter()
            .map(|&(i, j, w)| Json::arr_i32(&[i as i32, j as i32, -w]))
            .collect(),
    );
    let mut fields = vec![
        ("type", Json::str("solve")),
        ("id", Json::num(id as f64)),
        ("n", Json::num(g.n as f64)),
        ("edges", edges),
        ("replicas", Json::num(4.0)),
        ("max_periods", Json::num(32.0)),
        ("seed", Json::num(seed as f64)),
    ];
    fields.extend(extra.iter().cloned());
    Json::obj(fields).to_string()
}

fn ask(coord: &Coordinator, line: &str) -> Json {
    Json::parse(&handle_line(&coord.router, line)).unwrap()
}

/// The recorded stream with wall-clock timestamps stripped: everything
/// that must be bit-identical between equal-seed runs.
fn events(s: &TraceSink) -> Vec<(u64, TraceEvent)> {
    let mut out = Vec::new();
    for r in s.borrow().records() {
        out.push((r.seq, r.event.clone()));
    }
    out
}

#[test]
fn traced_solve_is_bit_identical_and_monotone() {
    // The core telemetry contract: tracing observes, never perturbs.
    // Two equal-seed traced runs must record identical event streams,
    // and the traced outcome must equal the untraced one field for
    // field.
    let g = Graph::random(20, 0.3, &mut Rng::new(91));
    let problem = reductions::max_cut(&g);
    let p = params(6, 64, 17);

    let sink_a = sink(DEFAULT_TRACE_CAP);
    let out_a = solve_with_trace(&problem, &p, EngineSelect::Native, Some(&sink_a)).unwrap();
    let sink_b = sink(DEFAULT_TRACE_CAP);
    let out_b = solve_with_trace(&problem, &p, EngineSelect::Native, Some(&sink_b)).unwrap();
    let untraced = solve_native(&problem, &p).unwrap();

    // Tracing perturbed nothing: traced == untraced, bit for bit.
    assert_eq!(out_a.best_energy, untraced.best_energy);
    assert_eq!(out_a.best_spins, untraced.best_spins);
    assert_eq!(out_a.best_phases, untraced.best_phases);
    assert_eq!(out_a.periods, untraced.periods);
    assert_eq!(out_a.settled_replicas, untraced.settled_replicas);
    assert_eq!(out_a.chunks, untraced.chunks);
    assert_eq!(out_b.best_energy, untraced.best_energy);

    // Equal seeds record equal event streams (timestamps excluded —
    // they are wall-clock, everything else must match exactly).
    let ev_a = events(&sink_a);
    let ev_b = events(&sink_b);
    assert!(!ev_a.is_empty());
    assert_eq!(ev_a, ev_b, "equal-seed traces must be bit-identical");

    // The lifecycle brackets: starts with solve_start, ends with
    // solve_end, and the engine recorded its chunk spans.
    assert!(matches!(ev_a.first().unwrap().1, TraceEvent::SolveStart { .. }));
    assert!(matches!(ev_a.last().unwrap().1, TraceEvent::SolveEnd { .. }));
    let has_engine_span = ev_a
        .iter()
        .any(|(_, e)| matches!(e, TraceEvent::EngineChunk { engine: "native", .. }));
    assert!(has_engine_span, "the native engine must record chunk spans");

    // Per-chunk running best energy is monotone non-increasing.
    let trajectory: Vec<f64> = ev_a
        .iter()
        .filter_map(|(_, e)| match e {
            TraceEvent::Chunk { best_energy, .. } => Some(*best_energy),
            _ => None,
        })
        .collect();
    assert!(!trajectory.is_empty(), "chunk events must be recorded");
    assert!(
        trajectory.windows(2).all(|w| w[1] <= w[0] + 1e-12),
        "best energy regressed: {trajectory:?}"
    );
    // The final outcome is at least as good as the last chunk's best
    // (readout polish may improve it further, never worsen it).
    assert!(out_a.best_energy <= trajectory.last().unwrap() + 1e-9);

    // The JSONL export round-trips through the schema validator.
    let jsonl = sink_a.borrow().to_jsonl();
    assert_eq!(validate_trace_jsonl(&jsonl).unwrap(), ev_a.len());
}

#[test]
fn sharded_trace_carries_engine_sync_spans() {
    // The sharded fabric's engine_chunk spans must meter all-gather
    // rounds, and tracing must not disturb the native/sharded
    // bit-exactness contract.
    let g = Graph::random(14, 0.3, &mut Rng::new(92));
    let problem = reductions::max_cut(&g);
    let p = params(4, 32, 23);
    let trace = sink(DEFAULT_TRACE_CAP);
    let select = EngineSelect::Sharded { shards: 2 };
    let sharded = solve_with_trace(&problem, &p, select, Some(&trace)).unwrap();
    let native = solve_native(&problem, &p).unwrap();
    assert_eq!(sharded.best_energy, native.best_energy);
    assert_eq!(sharded.best_phases, native.best_phases);
    let rec = trace.borrow();
    let sync_total: u64 = rec
        .records()
        .iter()
        .filter_map(|r| match &r.event {
            TraceEvent::EngineChunk { engine: "sharded", sync_rounds, .. } => Some(*sync_rounds),
            _ => None,
        })
        .sum();
    assert!(sync_total > 0, "a sharded solve pays all-gather rounds");
    assert_eq!(
        sync_total, sharded.sync_rounds,
        "per-chunk sync deltas must sum to the outcome's total"
    );
}

#[test]
fn wire_trace_attachment_is_optional_and_valid() {
    let coord = Coordinator::start(vec![], BatchPolicy::default()).unwrap();
    let g = Graph::random(10, 0.4, &mut Rng::new(93));

    // Untraced request: the response must not carry a trace key (the
    // pre-telemetry wire stays byte-compatible).
    let plain = ask(&coord, &solve_line(1, &g, 5, &[]));
    assert!(plain.get("error").is_none(), "{plain}");
    assert!(plain.get("trace").is_none(), "untraced responses carry no trace");

    // "trace": false behaves exactly like an absent field.
    let explicit_off = ask(&coord, &solve_line(2, &g, 5, &[("trace", Json::Bool(false))]));
    assert!(explicit_off.get("trace").is_none());

    // "trace": true attaches the lifecycle records; the same solve
    // fields come back unchanged.
    let traced = ask(&coord, &solve_line(3, &g, 5, &[("trace", Json::Bool(true))]));
    assert!(traced.get("error").is_none(), "{traced}");
    assert_eq!(traced.get("energy"), plain.get("energy"));
    assert_eq!(traced.get("spins"), plain.get("spins"));
    assert_eq!(traced.get("periods"), plain.get("periods"));
    let records = traced.get("trace").and_then(Json::as_arr).expect("trace array");
    assert!(!records.is_empty());
    let first = records.first().unwrap();
    assert_eq!(first.get("event").and_then(Json::as_str), Some("solve_start"));
    let last = records.last().unwrap();
    assert_eq!(last.get("event").and_then(Json::as_str), Some("solve_end"));
    // The attachment is schema-valid line by line.
    let jsonl: String = records.iter().map(|r| format!("{r}\n")).collect();
    assert_eq!(validate_trace_jsonl(&jsonl).unwrap(), records.len());

    coord.shutdown().unwrap();
}

#[test]
fn metrics_command_scrapes_a_live_mixed_engine_pool() {
    use std::io::{BufRead, BufReader, Write};
    // One pool serves native, sharded (per-request override), and rtl
    // (per-request override) solves over real TCP; the metrics command
    // must then report per-engine counters and latency percentiles.
    let coord = Coordinator::start(vec![], BatchPolicy::default()).unwrap();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let router = Arc::clone(&coord.router);
    std::thread::spawn(move || {
        let _ = serve_tcp(router, listener);
    });

    let stream = std::net::TcpStream::connect(addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    let mut call = |line: &str| -> Json {
        w.write_all(line.as_bytes()).unwrap();
        w.write_all(b"\n").unwrap();
        let mut resp = String::new();
        r.read_line(&mut resp).unwrap();
        Json::parse(resp.trim()).unwrap_or_else(|e| panic!("bad response {resp}: {e}"))
    };

    let g = Graph::random(10, 0.4, &mut Rng::new(94));
    let native = call(&solve_line(11, &g, 7, &[]));
    assert!(native.get("error").is_none(), "{native}");
    assert_eq!(native.get("engine").and_then(Json::as_str), Some("native"));
    let sharded = call(&solve_line(12, &g, 7, &[("shards", Json::num(2.0))]));
    assert!(sharded.get("error").is_none(), "{sharded}");
    assert_eq!(sharded.get("engine").and_then(Json::as_str), Some("sharded"));
    let rtl = call(&solve_line(13, &g, 7, &[("rtl", Json::Bool(true))]));
    assert!(rtl.get("error").is_none(), "{rtl}");
    assert_eq!(rtl.get("engine").and_then(Json::as_str), Some("rtl"));

    let m = call(r#"{"type":"metrics"}"#);
    assert_eq!(m.get("type").and_then(Json::as_str), Some("metrics"));
    let snap = m.get("snapshot").expect("snapshot object");
    assert_eq!(snap.get("solves_completed").and_then(Json::as_usize), Some(3));
    assert_eq!(snap.get("solves_native").and_then(Json::as_usize), Some(1));
    assert_eq!(snap.get("solves_sharded").and_then(Json::as_usize), Some(1));
    assert_eq!(snap.get("solves_rtl").and_then(Json::as_usize), Some(1));
    assert!(
        snap.get("solve_sync_rounds").and_then(Json::as_usize).unwrap() > 0,
        "the sharded solve must surface its sync cost"
    );
    assert!(
        snap.get("solve_fast_cycles").and_then(Json::as_usize).unwrap() > 0,
        "the rtl solve must surface its emulated cycles"
    );
    // Percentile fields: pool-wide and per engine kind, ordered and
    // consistent with the per-kind counters.
    for (key, want_count) in [
        ("solve", 3usize),
        ("solve_native", 1),
        ("solve_sharded", 1),
        ("solve_rtl", 1),
    ] {
        let s = snap.get(key).unwrap_or_else(|| panic!("missing {key}"));
        assert_eq!(s.get("count").and_then(Json::as_usize), Some(want_count), "{key}");
        let q = |f: &str| s.get(f).and_then(Json::as_f64).unwrap_or(-1.0);
        let (p50, p90, p99) = (q("p50_ms"), q("p90_ms"), q("p99_ms"));
        assert!(p50 > 0.0 && p50 <= p90 && p90 <= p99, "{key}: {p50} {p90} {p99}");
        assert!(q("mean_ms") > 0.0, "{key} saw real samples");
    }
    let text = m.get("prometheus").and_then(Json::as_str).unwrap();
    for needle in [
        "onn_solves_by_engine{engine=\"native\"} 1",
        "onn_solves_by_engine{engine=\"sharded\"} 1",
        "onn_solves_by_engine{engine=\"rtl\"} 1",
        "onn_solve_latency_ms{quantile=\"0.99\"}",
        "onn_solve_latency_rtl_ms_count 1",
        "# TYPE onn_solve_latency_ms summary",
    ] {
        assert!(text.contains(needle), "prometheus text missing {needle}:\n{text}");
    }

    coord.shutdown().unwrap();
}
