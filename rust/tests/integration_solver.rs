//! Solver subsystem integration: the annealed batched portfolio on the
//! native chunk engine, the coordinator's SolveRequest path end-to-end
//! over TCP JSON-lines, and the ONN-vs-SA quality contract the harness
//! demonstrates.

use std::sync::Arc;

use onn_scale::coordinator::batcher::BatchPolicy;
use onn_scale::coordinator::job::SolveRequest;
use onn_scale::coordinator::server::{
    handle_line, serve_tcp, Coordinator, EngineKind, PoolSpec, SolverPoolConfig,
};
use onn_scale::harness::datasets::benchmark_by_name;
use onn_scale::harness::solverbench;
use onn_scale::solver::anneal::Schedule;
use onn_scale::solver::graph::Graph;
use onn_scale::solver::portfolio::{solve_native, PortfolioParams};
use onn_scale::solver::{reductions, sa};
use onn_scale::util::json::Json;
use onn_scale::util::rng::Rng;

fn portfolio_params(replicas: usize, max_periods: usize, seed: u64) -> PortfolioParams {
    PortfolioParams {
        replicas,
        max_periods,
        seed,
        ..Default::default()
    }
}

#[test]
fn portfolio_never_worse_than_best_initial_replica() {
    let mut rng = Rng::new(41);
    for trial in 0..4 {
        let g = Graph::random(24, 0.2, &mut rng);
        let problem = reductions::max_cut(&g);
        let out = solve_native(&problem, &portfolio_params(8, 64, 500 + trial)).unwrap();
        assert!(
            out.best_energy <= out.initial_best_energy + 1e-9,
            "trial {trial}: best {} vs initial {}",
            out.best_energy,
            out.initial_best_energy
        );
        // The decode relation is monotone: lower energy = larger cut.
        let best_cut = g.cut_value(&out.best_spins);
        let initial_cut = reductions::cut_from_energy(&g, out.initial_best_energy);
        assert!(
            best_cut as f64 >= initial_cut - 1e-9,
            "trial {trial}: cut {best_cut} vs initial {initial_cut}"
        );
    }
}

#[test]
fn portfolio_matches_or_beats_sa_on_g64() {
    // The acceptance contract: on G(n=64, p=0.1), the batched annealed
    // portfolio holds its own against SA given the same number of
    // elementary spin updates.  The harness's solve-bench CLI prints the
    // full table; here two instances with a safety margin keep the suite
    // fast and deterministic.
    let report = solverbench::quality_vs_sa(64, 0.1, 2, 24, 128, 4242);
    assert!(
        report.ratio() >= 0.95,
        "ONN mean {} fell behind SA mean {} (ratio {})\n{}",
        report.onn_mean(),
        report.sa_mean(),
        report.ratio(),
        report.table()
    );
}

#[test]
fn coordinator_serves_solve_requests_in_process() {
    let coord = Coordinator::start(vec![], BatchPolicy::default()).unwrap();
    assert!(coord.router.has_solver());
    let g = Graph::complete_bipartite(3, 3);
    let mut req = SolveRequest::new(coord.next_id(), reductions::max_cut(&g));
    req.replicas = 8;
    req.max_periods = 64;
    req.seed = 9;
    let res = coord.solve_sync(req).unwrap();
    // K_{3,3} has no non-optimal strict local minima, so the polished
    // portfolio result is exactly the max cut.
    assert_eq!(g.cut_value(&res.spins), 9);
    assert!((res.energy - (-9.0)).abs() < 1e-9, "energy {}", res.energy);
    assert_eq!(res.replicas, 8);
    assert!(res.total_latency >= res.queue_latency);
    let snap = coord.snapshot();
    assert_eq!(snap.solves_submitted, 1);
    assert_eq!(snap.solves_completed, 1);
    assert_eq!(snap.solves_failed, 0);
    assert!(snap.solve_periods > 0);
    coord.shutdown().unwrap();
}

#[test]
fn solve_and_retrieval_share_the_wire() {
    // One coordinator, both job classes through handle_line.
    let set = benchmark_by_name("3x3").unwrap();
    let coord = Coordinator::start(
        vec![PoolSpec::new(set.cfg, set.weights.clone(), EngineKind::Native)],
        BatchPolicy::default(),
    )
    .unwrap();

    // Retrieval line (untyped, the legacy format).
    use onn_scale::onn::phase::spin_to_phase;
    let phases: Vec<i32> = set.dataset.patterns[0]
        .spins
        .iter()
        .map(|&s| spin_to_phase(s, 16))
        .collect();
    let req = Json::obj(vec![
        ("id", Json::num(1.0)),
        ("n", Json::num(9.0)),
        ("phases", Json::arr_i32(&phases)),
    ]);
    let resp = handle_line(&coord.router, &req.to_string());
    let v = Json::parse(&resp).unwrap();
    assert!(v.get("error").is_none(), "{resp}");
    assert_eq!(v.get("settled").and_then(Json::as_usize), Some(0));

    // Solve line (typed).
    let g = Graph::complete_bipartite(3, 3);
    let edges = Json::Arr(
        g.edges
            .iter()
            .map(|&(i, j, w)| Json::arr_i32(&[i as i32, j as i32, -(w)]))
            .collect(),
    );
    let req = Json::obj(vec![
        ("type", Json::str("solve")),
        ("id", Json::num(2.0)),
        ("n", Json::num(6.0)),
        ("edges", edges),
        ("replicas", Json::num(8.0)),
        ("max_periods", Json::num(64.0)),
        ("seed", Json::num(3.0)),
    ]);
    let resp = handle_line(&coord.router, &req.to_string());
    let v = Json::parse(&resp).unwrap();
    assert!(v.get("error").is_none(), "{resp}");
    let spins: Vec<i8> = v
        .get("spins")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|x| x.as_i64().unwrap() as i8)
        .collect();
    assert_eq!(spins.len(), 6);
    assert_eq!(g.cut_value(&spins), 9);
    assert_eq!(v.get("energy").and_then(Json::as_f64), Some(-9.0));

    coord.shutdown().unwrap();
}

#[test]
fn solve_request_end_to_end_over_tcp() {
    use std::io::{BufRead, BufReader, Write};
    let set = benchmark_by_name("3x3").unwrap();
    let coord = Coordinator::start(
        vec![PoolSpec::new(set.cfg, set.weights.clone(), EngineKind::Native)],
        BatchPolicy::default(),
    )
    .unwrap();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let router = Arc::clone(&coord.router);
    std::thread::spawn(move || {
        let _ = serve_tcp(router, listener);
    });

    let stream = std::net::TcpStream::connect(addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    let line = r#"{"type":"solve","id":7,"n":6,"edges":[[0,3,-1],[0,4,-1],[0,5,-1],[1,3,-1],[1,4,-1],[1,5,-1],[2,3,-1],[2,4,-1],[2,5,-1]],"replicas":8,"max_periods":64,"schedule":"geometric","noise":0.5,"seed":5}"#;
    w.write_all(line.as_bytes()).unwrap();
    w.write_all(b"\n").unwrap();
    let mut resp = String::new();
    r.read_line(&mut resp).unwrap();
    let v = Json::parse(resp.trim()).unwrap();
    assert!(v.get("error").is_none(), "{resp}");
    assert_eq!(v.get("id").and_then(Json::as_usize), Some(7));
    let spins: Vec<i8> = v
        .get("spins")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|x| x.as_i64().unwrap() as i8)
        .collect();
    // The wire carried K_{3,3} couplings (J = -1 per edge): the served
    // answer must be the exact max cut.
    let g = Graph::complete_bipartite(3, 3);
    assert_eq!(g.cut_value(&spins), 9);
    assert_eq!(v.get("energy").and_then(Json::as_f64), Some(-9.0));
    assert_eq!(v.get("replicas").and_then(Json::as_usize), Some(8));

    // Malformed solve line comes back as an error, not a hang.
    let mut w2 = w;
    w2.write_all(br#"{"type":"solve","n":2}"#).unwrap();
    w2.write_all(b"\n").unwrap();
    let mut resp2 = String::new();
    r.read_line(&mut resp2).unwrap();
    assert!(resp2.contains("error"), "{resp2}");

    coord.shutdown().unwrap();
}

/// JSON-lines solve request for a random graph with J = -1 couplings.
fn solve_line_json(id: u64, g: &Graph, replicas: usize, max_periods: usize, seed: u64) -> String {
    let edges = Json::Arr(
        g.edges
            .iter()
            .map(|&(i, j, w)| Json::arr_i32(&[i as i32, j as i32, -w]))
            .collect(),
    );
    Json::obj(vec![
        ("type", Json::str("solve")),
        ("id", Json::num(id as f64)),
        ("n", Json::num(g.n as f64)),
        ("edges", edges),
        ("replicas", Json::num(replicas as f64)),
        ("max_periods", Json::num(max_periods as f64)),
        ("seed", Json::num(seed as f64)),
    ])
    .to_string()
}

#[test]
fn sharded_solve_over_tcp_matches_the_native_path() {
    use std::io::{BufRead, BufReader, Write};
    // A solver pool whose threshold forces sharding for n >= 12; the
    // same request served by a default pool (threshold 256) runs
    // native.  Same seed => identical trajectories => identical wire
    // answers, the distributed-faithfulness contract end to end.
    let sharded_coord = Coordinator::start_with_solver(
        vec![],
        BatchPolicy::default(),
        SolverPoolConfig {
            workers: 1,
            shard_threshold: 12,
            max_shards: 3,
            ..Default::default()
        },
    )
    .unwrap();
    let native_coord = Coordinator::start(vec![], BatchPolicy::default()).unwrap();

    let g = Graph::random(18, 0.3, &mut Rng::new(55));
    let line = solve_line_json(31, &g, 6, 64, 12);

    // Sharded pool over real TCP.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let router = Arc::clone(&sharded_coord.router);
    std::thread::spawn(move || {
        let _ = serve_tcp(router, listener);
    });
    let stream = std::net::TcpStream::connect(addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    w.write_all(line.as_bytes()).unwrap();
    w.write_all(b"\n").unwrap();
    let mut resp = String::new();
    r.read_line(&mut resp).unwrap();
    let sharded = Json::parse(resp.trim()).unwrap();
    assert!(sharded.get("error").is_none(), "{resp}");
    assert_eq!(sharded.get("engine").and_then(Json::as_str), Some("sharded"));
    let sync_rounds = sharded.get("sync_rounds").and_then(Json::as_usize).unwrap();
    assert!(sync_rounds > 0, "sharded solve must report its sync cost");

    // Native pool through the same line handler.
    let native = Json::parse(&handle_line(&native_coord.router, &line)).unwrap();
    assert!(native.get("error").is_none());
    assert_eq!(native.get("engine").and_then(Json::as_str), Some("native"));
    assert_eq!(native.get("sync_rounds").and_then(Json::as_usize), Some(0));

    // Equal seed => equal answer, field for field.
    assert_eq!(
        sharded.get("energy").and_then(Json::as_f64),
        native.get("energy").and_then(Json::as_f64)
    );
    assert_eq!(sharded.get("spins"), native.get("spins"));
    assert_eq!(sharded.get("phases"), native.get("phases"));
    assert_eq!(sharded.get("periods"), native.get("periods"));

    // The solve metrics expose the distributed sync cost.
    let snap = sharded_coord.snapshot();
    assert_eq!(snap.solves_completed, 1);
    assert_eq!(snap.solves_sharded, 1);
    assert_eq!(snap.solve_sync_rounds, sync_rounds as u64);
    let snap = native_coord.snapshot();
    assert_eq!(snap.solves_sharded, 0);
    assert_eq!(snap.solve_sync_rounds, 0);

    sharded_coord.shutdown().unwrap();
    native_coord.shutdown().unwrap();
}

#[test]
fn wire_shards_override_forces_the_sharded_engine() {
    // Below the default threshold, but the request line pins shards=2:
    // the pool must honor the override and still return the native
    // answer bit for bit.
    let coord = Coordinator::start(vec![], BatchPolicy::default()).unwrap();
    let g = Graph::random(10, 0.4, &mut Rng::new(77));
    let base = solve_line_json(41, &g, 4, 32, 9);
    let native = Json::parse(&handle_line(&coord.router, &base)).unwrap();
    assert_eq!(native.get("engine").and_then(Json::as_str), Some("native"));
    let with_override = format!("{}{}", &base[..base.len() - 1], ",\"shards\":2}");
    let sharded = Json::parse(&handle_line(&coord.router, &with_override)).unwrap();
    assert!(sharded.get("error").is_none(), "{sharded}");
    assert_eq!(sharded.get("engine").and_then(Json::as_str), Some("sharded"));
    assert!(sharded.get("sync_rounds").and_then(Json::as_usize).unwrap() > 0);
    assert_eq!(sharded.get("energy"), native.get("energy"));
    assert_eq!(sharded.get("spins"), native.get("spins"));
    coord.shutdown().unwrap();
}

#[test]
fn concurrent_small_solves_coalesce_and_match_the_unbatched_pool() {
    use std::io::{BufRead, BufReader, Write};
    use std::sync::Barrier;
    use std::time::Duration;
    // A packing pool with one worker and a generous window: N clients
    // submitting small solves concurrently over real TCP must coalesce
    // onto shared lane-block engines (occupancy > 1 in the metrics) and
    // each must receive byte-for-byte the response an unbatched pool
    // (packing disabled) serves for the same line.
    let packed_coord = Coordinator::start_with_solver(
        vec![],
        BatchPolicy::default(),
        SolverPoolConfig {
            workers: 1,
            pack_max_wait: Duration::from_millis(300),
            ..Default::default()
        },
    )
    .unwrap();
    let unbatched_coord = Coordinator::start_with_solver(
        vec![],
        BatchPolicy::default(),
        SolverPoolConfig {
            workers: 1,
            pack_max_oscillators: 0, // packing off: one engine per request
            ..Default::default()
        },
    )
    .unwrap();

    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let router = Arc::clone(&packed_coord.router);
    std::thread::spawn(move || {
        let _ = serve_tcp(router, listener);
    });

    // Same oscillator bucket (9..=12 -> 16) and same period budget, so
    // every request is pack-compatible; different graphs and seeds.
    let lines: Vec<String> = (0..4u64)
        .map(|i| {
            let g = Graph::random(9 + i as usize, 0.4, &mut Rng::new(300 + i));
            solve_line_json(100 + i, &g, 4, 32, 40 + i)
        })
        .collect();
    let barrier = Arc::new(Barrier::new(lines.len()));
    let handles: Vec<_> = lines
        .iter()
        .map(|line| {
            let line = line.clone();
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let stream = std::net::TcpStream::connect(addr).unwrap();
                let mut w = stream.try_clone().unwrap();
                let mut r = BufReader::new(stream);
                barrier.wait();
                w.write_all(line.as_bytes()).unwrap();
                w.write_all(b"\n").unwrap();
                let mut resp = String::new();
                r.read_line(&mut resp).unwrap();
                resp.trim().to_string()
            })
        })
        .collect();
    let responses: Vec<String> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    for (line, resp) in lines.iter().zip(&responses) {
        assert!(!resp.contains("error"), "{resp}");
        let want = handle_line(&unbatched_coord.router, line);
        assert_eq!(
            resp, &want,
            "packed pool answered differently from the unbatched pool"
        );
    }

    let snap = packed_coord.snapshot();
    assert_eq!(snap.solves_completed, 4);
    assert!(snap.solve_batches >= 1);
    assert!(
        snap.solve_batch_occupancy > 1.0,
        "no coalescing happened: occupancy {}",
        snap.solve_batch_occupancy
    );
    let snap = unbatched_coord.snapshot();
    assert!(
        (snap.solve_batch_occupancy - 1.0).abs() < 1e-9,
        "the unbatched pool must run one engine per request"
    );

    packed_coord.shutdown().unwrap();
    unbatched_coord.shutdown().unwrap();
}

#[test]
fn sector_problems_round_trip_through_portfolio() {
    // k-coloring (sectors = 3) on a 3-colorable graph: the sector
    // decoder plus recolor polish must produce a proper coloring.
    use onn_scale::apps::coloring::solve_onn;
    let g = Graph {
        n: 6,
        edges: vec![
            (0, 1, 1),
            (1, 2, 1),
            (2, 0, 1), // triangle needs 3 colors
            (3, 4, 1),
            (4, 5, 1),
            (5, 3, 1), // second triangle
            (0, 3, 1),
        ],
    };
    let res = solve_onn(&g, 3, 20, 96, 13);
    assert_eq!(res.conflicts, 0, "colors {:?}", res.colors);
}

#[test]
fn vertex_cover_served_and_repaired() {
    let mut rng = Rng::new(47);
    let g = Graph::random(12, 0.25, &mut rng);
    let problem = reductions::min_vertex_cover(&g, 2.0);
    let out = solve_native(&problem, &portfolio_params(8, 64, 3)).unwrap();
    let cover = reductions::decode_cover(&g, &out.best_spins);
    assert!(reductions::is_cover(&g, &cover));
    // The solved cover must not be larger than greedy-from-nothing.
    let baseline = reductions::decode_cover(&g, &vec![-1i8; g.n]);
    assert!(
        reductions::cover_size(&cover) <= reductions::cover_size(&baseline),
        "solved {} vs baseline {}",
        reductions::cover_size(&cover),
        reductions::cover_size(&baseline)
    );
}

#[test]
fn schedules_drive_noise_through_the_engine() {
    // A constant schedule with a large amplitude must leave the zero-J
    // problem's replicas scrambled mid-run but still finish noise-free:
    // the final chunk has level 0, so frozen dynamics settle again.
    use onn_scale::solver::problem::IsingProblem;
    let problem = IsingProblem::new(5);
    let params = PortfolioParams {
        replicas: 4,
        max_periods: 64,
        schedule: Schedule::Constant { level: 0.9 },
        seed: 8,
        plateau_chunks: 0,
        polish: false,
        ..Default::default()
    };
    let out = solve_native(&problem, &params).unwrap();
    assert!(out.noise_applied);
    // Zero couplings: every state has energy 0; with the noise-free
    // tail the frozen dynamics settle every replica.
    assert_eq!(out.settled_replicas, 4, "tail chunks must be noise-free");
    assert_eq!(out.best_energy, 0.0);
}

#[test]
fn all_settled_replicas_trigger_early_exit() {
    // Zero couplings freeze the dynamics the moment noise stops; with a
    // long budget (64 chunks, noise-free tail of 16) the portfolio must
    // stop at the first settled noise-free chunk instead of burning the
    // remaining budget.
    use onn_scale::solver::problem::IsingProblem;
    let problem = IsingProblem::new(4);
    let params = PortfolioParams {
        replicas: 4,
        max_periods: 512, // 64 chunks of 8
        schedule: Schedule::Geometric {
            start: 0.6,
            factor: 0.8,
        },
        seed: 21,
        polish: false,
        ..Default::default()
    };
    let out = solve_native(&problem, &params).unwrap();
    assert!(out.early_exit, "all-settled early exit never fired");
    assert!(
        out.chunks < 64,
        "burned the whole budget: {} chunks",
        out.chunks
    );
    assert_eq!(out.settled_replicas, 4);
}
