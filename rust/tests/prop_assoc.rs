//! Property tests for the online-learning associative memory: after any
//! store/forget sequence the delta-maintained quantized matrix must be
//! bit-identical to a cold retrain+quantize over the surviving pattern
//! set, and a recall served by a delta-reprogrammed *warm* arena engine
//! must return the exact spins a freshly built engine produces — on the
//! native, row-sharded, and bit-true rtl fabrics, across arena
//! hit/miss/evict interleavings.  Also pins the retrieval-path fixes
//! that ride along: empty-pattern-set learning no longer panics,
//! duplicate stores (exact or inverted) are idempotent, and LRU
//! eviction respects recency refreshes.

use onn_scale::coordinator::arena::{ArenaKey, EngineArena};
use onn_scale::coordinator::assoc::{AssocRegistry, LearningRule, MemorySpace};
use onn_scale::coordinator::metrics::Metrics;
use onn_scale::onn::config::NetworkConfig;
use onn_scale::onn::learning::{diederich_opper_i, hebbian, hebbian_counts};
use onn_scale::onn::patterns::spins_match_up_to_inversion;
use onn_scale::onn::phase::spin_to_phase;
use onn_scale::onn::weights::WeightMatrix;
use onn_scale::runtime::ChunkEngine;
use onn_scale::solver::portfolio::{
    build_engine_cfg, drive_retrieval, EngineSelect, DEFAULT_CHUNK,
};
use onn_scale::util::rng::Rng;

fn random_pattern(rng: &mut Rng, n: usize) -> Vec<i8> {
    (0..n).map(|_| rng.spin()).collect()
}

/// Cold-retrain the float master from a surviving pattern set exactly as
/// [`MemorySpace::master`] defines it, but from scratch — no shared
/// state with the incremental path under test.
fn cold_master(survivors: &[Vec<i8>], n: usize, rule: LearningRule) -> Vec<f32> {
    if survivors.is_empty() {
        return vec![0.0; n * n];
    }
    match rule {
        LearningRule::Hebbian => hebbian(survivors),
        LearningRule::Doi => diederich_opper_i(survivors, 0.5, 1000).weights,
    }
}

#[test]
fn prop_delta_quantized_bit_identical_to_cold_retrain() {
    // Random store/forget sequences on both learning rules: after every
    // mutation the delta-maintained quantized matrix equals quantizing
    // the cold-retrained master, bit for bit.
    let mut rng = Rng::new(4101);
    for case in 0..12 {
        let n = 8 + rng.usize_below(13); // 8..=20
        let capacity = 2 + rng.usize_below(3); // 2..=4
        let rule = if case % 2 == 0 {
            LearningRule::Hebbian
        } else {
            LearningRule::Doi
        };
        let cfg = NetworkConfig::paper(n);
        let mut ms = MemorySpace::new(n, capacity, rule);
        for _ in 0..16 {
            if ms.pattern_count() > 0 && rng.bool() && rng.bool() {
                // Forget a currently stored pattern (sometimes via its
                // inverse, which must resolve to the same entry).
                let stored = ms.stored_patterns();
                let mut victim = stored[rng.usize_below(stored.len())].clone();
                if rng.bool() {
                    for s in &mut victim {
                        *s = -*s;
                    }
                }
                ms.forget(&victim).unwrap();
            } else {
                ms.store(random_pattern(&mut rng, n)).unwrap();
            }
            let survivors = ms.stored_patterns();
            let cold = WeightMatrix::quantize(&cold_master(&survivors, n, rule), n, &cfg);
            assert_eq!(
                ms.weights(),
                &cold,
                "case {case} ({rule:?}, n={n}): delta path diverged from cold rebuild"
            );
        }
    }
}

#[test]
fn prop_integer_counts_match_batch_hebbian_training() {
    // The bit-identity argument rests on the integer count master:
    // accumulating patterns one by one (in any order, with removals)
    // must land on the exact counts of batch training over the
    // survivors.
    let mut rng = Rng::new(4102);
    for _ in 0..10 {
        let n = 5 + rng.usize_below(20);
        let mut pats: Vec<Vec<i8>> = (0..6).map(|_| random_pattern(&mut rng, n)).collect();
        let mut ms = MemorySpace::new(n, 6, LearningRule::Hebbian);
        for p in &pats {
            ms.store(p.clone()).unwrap();
        }
        let drop_idx = rng.usize_below(pats.len());
        ms.forget(&pats[drop_idx]).unwrap();
        pats.remove(drop_idx);
        assert_eq!(ms.master(), hebbian(&pats), "incremental master != batch master");
        let counts = hebbian_counts(&pats);
        let from_counts: Vec<f32> = counts.iter().map(|&c| c as f32 / n as f32).collect();
        assert_eq!(hebbian(&pats), from_counts, "hebbian != counts/N");
    }
}

#[test]
fn prop_warm_delta_recall_bit_identical_across_fabrics() {
    // The tentpole serving contract: a warm arena engine reprogrammed
    // via set_weights with the delta-maintained quantized matrix
    // settles any probe to the exact spins of a freshly built engine
    // loaded with the cold retrain+quantize matrix.  Exercised on all
    // three fabrics through a miss -> hit -> evict -> miss -> hit arena
    // interleaving (capacity-1 arena churned by a different-geometry
    // checkout).
    let selects = [
        EngineSelect::Native,
        EngineSelect::Sharded { shards: 2 },
        EngineSelect::Rtl,
    ];
    for (fi, &select) in selects.iter().enumerate() {
        let n = 12;
        let cfg = NetworkConfig::paper(n);
        let period = cfg.period() as i32;
        let metrics = Metrics::default();
        let mut arena = EngineArena::new(1);
        let mut ms = MemorySpace::new(n, 3, LearningRule::Hebbian);
        let mut rng = Rng::new(4200 + fi as u64);
        let key = ArenaKey::for_recall(n, select);
        let mut builds = 0usize;
        for step in 0..4 {
            // Mutate between recalls so the warm engine really is
            // reprogrammed (never just reused with stale weights).
            ms.store(random_pattern(&mut rng, n)).unwrap();
            let snap = ms.snapshot();
            let survivors = ms.stored_patterns();
            let cold = WeightMatrix::quantize(
                &cold_master(&survivors, n, LearningRule::Hebbian),
                n,
                &cfg,
            )
            .to_f32();
            assert_eq!(snap.weights_f32, cold, "{select:?}: snapshot != cold quantize");

            let probe = random_pattern(&mut rng, n);
            let init: Vec<i32> = probe.iter().map(|&s| spin_to_phase(s, period)).collect();
            let mut warm = arena
                .checkout(key, &metrics, || {
                    builds += 1;
                    build_engine_cfg(cfg, 1, DEFAULT_CHUNK, select)
                })
                .unwrap();
            warm.set_weights(&snap.weights_f32).unwrap();
            let (wp, ws) = drive_retrieval(warm.as_mut(), &init, 32).unwrap();
            arena.checkin(key, warm, &metrics);

            let mut fresh = build_engine_cfg(cfg, 1, DEFAULT_CHUNK, select).unwrap();
            fresh.set_weights(&cold).unwrap();
            let (cp, cs) = drive_retrieval(fresh.as_mut(), &init, 32).unwrap();
            assert_eq!(wp, cp, "{select:?} step {step}: warm phases != cold phases");
            assert_eq!(ws, cs, "{select:?} step {step}: settle periods diverged");

            if step == 1 {
                // Churn: a different-geometry checkin overflows the
                // capacity-1 arena and evicts the warm recall engine,
                // so the next recall rebuilds cold (miss) and the one
                // after that hits again.
                let other = ArenaKey::for_recall(9, EngineSelect::Native);
                let e = arena
                    .checkout(other, &metrics, || {
                        build_engine_cfg(
                            NetworkConfig::paper(9),
                            1,
                            DEFAULT_CHUNK,
                            EngineSelect::Native,
                        )
                    })
                    .unwrap();
                arena.checkin(other, e, &metrics);
            }
        }
        assert_eq!(
            builds, 2,
            "{select:?}: expected miss -> hit -> evict -> miss -> hit (2 builds)"
        );
    }
}

#[test]
fn prop_duplicate_stores_idempotent_including_inverse() {
    let mut rng = Rng::new(4103);
    let n = 16;
    let mut ms = MemorySpace::new(n, 4, LearningRule::Hebbian);
    let p = random_pattern(&mut rng, n);
    let first = ms.store(p.clone()).unwrap();
    assert!(!first.duplicate);
    let w1 = ms.weights().clone();
    let v1 = ms.version();

    let again = ms.store(p.clone()).unwrap();
    assert!(again.duplicate, "exact re-store is a duplicate");
    assert_eq!(again.patterns, 1);
    assert_eq!(again.delta_entries, 0, "duplicates reprogram nothing");

    let inverse: Vec<i8> = p.iter().map(|&s| -s).collect();
    let inv = ms.store(inverse).unwrap();
    assert!(inv.duplicate, "an inverted pattern's outer product is identical");
    assert_eq!(inv.patterns, 1);

    assert_eq!(ms.weights(), &w1, "duplicate stores must not touch the matrix");
    assert_eq!(ms.version(), v1, "duplicate stores must not bump the version");
}

#[test]
fn prop_lru_eviction_respects_recency_refresh() {
    // capacity 2: store a, b; refresh a's recency with a duplicate
    // store; storing c must evict b (the least recently used), and the
    // matrix must equal a cold retrain over {a, c}.
    let n = 12;
    let cfg = NetworkConfig::paper(n);
    let mut rng = Rng::new(4104);
    let a = random_pattern(&mut rng, n);
    let mut b = a.clone();
    let mut c = a.clone();
    b[0] = -b[0];
    b[1] = -b[1];
    c[2] = -c[2];
    c[3] = -c[3];
    let mut ms = MemorySpace::new(n, 2, LearningRule::Hebbian);
    ms.store(a.clone()).unwrap();
    ms.store(b.clone()).unwrap();
    assert!(ms.store(a.clone()).unwrap().duplicate, "recency refresh");
    let out = ms.store(c.clone()).unwrap();
    assert_eq!(out.evicted, 1, "store past capacity evicts exactly one");
    let survivors = ms.stored_patterns();
    assert!(survivors.iter().any(|s| spins_match_up_to_inversion(s, &a)));
    assert!(survivors.iter().any(|s| spins_match_up_to_inversion(s, &c)));
    assert!(
        !survivors.iter().any(|s| spins_match_up_to_inversion(s, &b)),
        "b was LRU and must be the eviction victim"
    );
    let cold = WeightMatrix::quantize(&hebbian(&survivors), n, &cfg);
    assert_eq!(ms.weights(), &cold, "post-eviction matrix != cold rebuild");
}

#[test]
fn prop_drained_space_and_empty_training_are_safe() {
    // The satellite bugfix: the wire-reachable store -> forget path can
    // drain a space to zero patterns, which used to panic inside the
    // learning rules on `patterns[0]`.
    assert!(hebbian(&[]).is_empty());
    assert!(hebbian_counts(&[]).is_empty());
    let doi = diederich_opper_i(&[], 0.5, 10);
    assert!(doi.converged && doi.weights.is_empty() && doi.epochs == 0);

    let mut rng = Rng::new(4105);
    let n = 10;
    for rule in [LearningRule::Hebbian, LearningRule::Doi] {
        let mut ms = MemorySpace::new(n, 3, rule);
        let p = random_pattern(&mut rng, n);
        ms.store(p.clone()).unwrap();
        ms.forget(&p).unwrap();
        assert_eq!(ms.pattern_count(), 0);
        assert_eq!(ms.weights(), &WeightMatrix::zeros(n), "{rule:?}: drained != zeros");
        let snap = ms.snapshot();
        assert!(snap.patterns.is_empty());
        assert_eq!(snap.weights_f32, vec![0.0; n * n]);
        // A drained space still serves: the settle runs on the zero
        // matrix and simply never matches.
        let cfg = NetworkConfig::paper(n);
        let period = cfg.period() as i32;
        let init: Vec<i32> = p.iter().map(|&s| spin_to_phase(s, period)).collect();
        let mut engine = build_engine_cfg(cfg, 1, DEFAULT_CHUNK, EngineSelect::Native).unwrap();
        engine.set_weights(&snap.weights_f32).unwrap();
        drive_retrieval(engine.as_mut(), &init, 8).unwrap();
    }
}

#[test]
fn prop_registry_store_never_leaks_an_empty_space() {
    // A malformed *first* store must not leave a half-created space
    // behind (the second satellite retrieval-path fix).
    let metrics = Metrics::default();
    let reg = AssocRegistry::new();
    assert!(reg.store("s", vec![1, 0, -1], None, None, &metrics).is_err());
    assert!(reg.store("s", Vec::new(), None, None, &metrics).is_err());
    assert_eq!(reg.space_count(), 0, "failed creation leaked a space");
    reg.store("s", vec![1, -1, 1, -1, 1, -1, 1, -1, 1], None, None, &metrics)
        .unwrap();
    assert_eq!(reg.space_count(), 1);
    // Capacity/rule pinning: an existing space rejects mismatched
    // overrides instead of silently invalidating its stored patterns.
    assert!(reg.store("s", vec![1; 9], Some(7), None, &metrics).is_err());
    assert!(reg
        .store("s", vec![1; 9], None, Some(LearningRule::Doi), &metrics)
        .is_err());
}
