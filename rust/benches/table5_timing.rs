//! Bench: regenerate paper Table 5 (max frequencies / max oscillators)
//! and time the timing-model sweep.

use onn_scale::fpga::device::zynq7020;
use onn_scale::fpga::timing::frequencies;
use onn_scale::harness::bench::run;
use onn_scale::harness::report;
use onn_scale::onn::config::NetworkConfig;

fn main() {
    println!("{}", report::table5());
    let d = zynq7020();
    run("table5/frequency_model_full_sweep", 3, 100, || {
        let mut acc = 0.0;
        for n in (4..=506).step_by(2) {
            let (fl, fo) = frequencies("hybrid", &NetworkConfig::paper(n), &d);
            acc += fl + fo;
        }
        assert!(acc > 0.0);
    });
}
