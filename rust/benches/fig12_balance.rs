//! Bench: paper Figure 12 (hybrid area vs frequency balance point).

use onn_scale::harness::bench::run;
use onn_scale::harness::report;
use onn_scale::harness::scaling::{fig12_balance, fig12_crossover, hybrid_sweep};

fn main() {
    println!("{}", report::fig12());
    run("fig12/balance_sweep_and_crossover", 3, 50, || {
        let sweep = hybrid_sweep();
        let bal = fig12_balance(&sweep);
        assert!(fig12_crossover(&bal).is_some());
    });
}
