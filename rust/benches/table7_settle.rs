//! Bench: paper Table 7 (mean time to settle) at bench scale, plus
//! settle-loop timing on the functional engine.

use onn_scale::harness::bench::run;
use onn_scale::harness::datasets::benchmark_by_name;
use onn_scale::harness::report::RetrievalReport;
use onn_scale::harness::retrieval::{run_cell, Engine, CORRUPTION_LEVELS};
use onn_scale::onn::dynamics::FunctionalEngine;
use onn_scale::onn::phase::spin_to_phase;
use onn_scale::util::rng::Rng;

fn main() {
    let trials = 60;
    let mut cells = Vec::new();
    for name in ["3x3", "5x4", "7x6", "10x10", "22x22"] {
        let set = benchmark_by_name(name).unwrap();
        let ra_ok = set.cfg.n <= 48;
        for pct in CORRUPTION_LEVELS {
            let ha = run_cell(&set, pct, trials, 2025, Engine::Native).unwrap();
            let ra = ra_ok.then(|| run_cell(&set, pct, trials, 2025, Engine::RtlRecurrent).unwrap());
            cells.push((set.dataset.name.clone(), pct, ra, ha));
        }
    }
    println!("{}", RetrievalReport { cells }.table7());

    // settle-loop micro-bench at the paper's headline scale
    let set = benchmark_by_name("22x22").unwrap();
    let mut eng = FunctionalEngine::new(set.cfg, set.weights.clone());
    let mut rng = Rng::new(3);
    let target = &set.dataset.patterns[0];
    run("table7/settle_22x22_single_trial_25pct", 1, 10, || {
        let corrupted = target.corrupt(121, &mut rng);
        let init: Vec<i32> = corrupted
            .spins
            .iter()
            .map(|&s| spin_to_phase(s, 16))
            .collect();
        let out = eng.run_to_settle(&init, 256);
        assert!(out.settled.is_some());
    });
}
