//! Bench: paper Figure 9 (LUT scaling, log-log slopes) + sweep timing.

use onn_scale::harness::bench::run;
use onn_scale::harness::report;
use onn_scale::harness::scaling::{hybrid_sweep, recurrent_sweep};

fn main() {
    println!("{}", report::fig9());
    run("fig9/sweep_and_fit_both_architectures", 3, 50, || {
        let ra = recurrent_sweep().lut_fit();
        let ha = hybrid_sweep().lut_fit();
        assert!(ra.slope > ha.slope);
    });
}
