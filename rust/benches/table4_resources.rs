//! Bench: regenerate paper Table 4 (resource usage at max N per design)
//! and time the resource-model evaluation + capacity search.

use onn_scale::fpga::device::zynq7020;
use onn_scale::fpga::resources::{estimate, max_oscillators};
use onn_scale::harness::bench::run;
use onn_scale::harness::report;
use onn_scale::onn::config::NetworkConfig;

fn main() {
    println!("{}", report::table4());
    let d = zynq7020();
    run("table4/estimate_hybrid_506", 3, 50, || {
        let r = estimate("hybrid", &NetworkConfig::paper(506), &d);
        assert!(r.dsps > 0);
    });
    run("table4/estimate_recurrent_48", 3, 50, || {
        let r = estimate("recurrent", &NetworkConfig::paper(48), &d);
        assert!(r.luts > 0);
    });
    run("table4/max_oscillators_search_both", 1, 10, || {
        let ra = max_oscillators("recurrent", &d, 4, 5);
        let ha = max_oscillators("hybrid", &d, 4, 5);
        assert!(ha > ra);
    });
}
