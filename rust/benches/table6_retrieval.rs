//! Bench: paper Table 6 (retrieval accuracy) at bench scale — RA on the
//! cycle-accurate recurrent simulator for feasible sizes, HA on the
//! functional engine — printing the table and timing each cell kind.
//!
//! Full-scale regeneration (1000 trials, PJRT): `onn-scale table6 --trials 1000`.

use onn_scale::harness::bench::run;
use onn_scale::harness::datasets::benchmark_by_name;
use onn_scale::harness::report::RetrievalReport;
use onn_scale::harness::retrieval::{run_cell, Engine, CORRUPTION_LEVELS};

fn main() {
    let trials = 60;
    let mut cells = Vec::new();
    for name in ["3x3", "5x4", "7x6", "10x10", "22x22"] {
        let set = benchmark_by_name(name).unwrap();
        let ra_ok = set.cfg.n <= 48;
        for pct in CORRUPTION_LEVELS {
            let ha = run_cell(&set, pct, trials, 2025, Engine::Native).unwrap();
            let ra = ra_ok.then(|| run_cell(&set, pct, trials, 2025, Engine::RtlRecurrent).unwrap());
            cells.push((set.dataset.name.clone(), pct, ra, ha));
        }
    }
    println!("{}", RetrievalReport { cells }.table6());

    let set = benchmark_by_name("7x6").unwrap();
    run("table6/cell_native_7x6_25pct_20trials", 1, 5, || {
        let c = run_cell(&set, 25.0, 20, 1, Engine::Native).unwrap();
        assert_eq!(c.trials, 100);
    });
    run("table6/cell_rtl_recurrent_7x6_25pct_20trials", 1, 3, || {
        let c = run_cell(&set, 25.0, 20, 1, Engine::RtlRecurrent).unwrap();
        assert_eq!(c.trials, 100);
    });
}
