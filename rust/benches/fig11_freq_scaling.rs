//! Bench: paper Figure 11 (oscillation frequency vs N, log-log slopes).

use onn_scale::harness::bench::run;
use onn_scale::harness::report;
use onn_scale::harness::scaling::{hybrid_sweep, recurrent_sweep};

fn main() {
    println!("{}", report::fig11());
    run("fig11/sweep_and_fit_both_architectures", 3, 50, || {
        let ra = recurrent_sweep().freq_fit();
        let ha = hybrid_sweep().freq_fit();
        assert!(ra.slope < 0.0 && ha.slope < ra.slope);
    });
}
