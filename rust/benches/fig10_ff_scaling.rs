//! Bench: paper Figure 10 (flip-flop scaling, log-log slopes).

use onn_scale::harness::bench::run;
use onn_scale::harness::report;
use onn_scale::harness::scaling::{hybrid_sweep, recurrent_sweep};

fn main() {
    println!("{}", report::fig10());
    run("fig10/sweep_and_fit_both_architectures", 3, 50, || {
        let ra = recurrent_sweep().ff_fit();
        let ha = hybrid_sweep().ff_fit();
        assert!(ra.slope > ha.slope);
    });
}
