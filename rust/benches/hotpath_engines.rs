//! Hot-path benchmark: the engines that execute retrieval trials —
//! native incremental vs naive oracle vs PJRT artifact vs RTL sims —
//! plus coordinator throughput.  This is the §Perf workhorse
//! (EXPERIMENTS.md records before/after from here).

use std::sync::Arc;
use std::time::Duration;

use onn_scale::coordinator::batcher::BatchPolicy;
use onn_scale::coordinator::job::RetrievalRequest;
use onn_scale::coordinator::server::{Coordinator, EngineKind, PoolSpec};
use onn_scale::harness::bench::run;
use onn_scale::harness::datasets::benchmark_by_name;
use onn_scale::onn::dynamics::{period_step_naive, FunctionalEngine};
use onn_scale::rtl::recurrent::RecurrentOnn;
use onn_scale::rtl::RtlSim;
use onn_scale::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(1);

    // --- L3-native period step: naive vs incremental, 22x22 scale ---
    let set = benchmark_by_name("22x22").unwrap();
    let n = set.cfg.n;
    let ph0: Vec<i32> = (0..n).map(|_| rng.range_i64(0, 16) as i32).collect();
    run("native/period_step_naive_n484", 1, 5, || {
        let out = period_step_naive(&set.cfg, &set.weights, &ph0);
        assert_eq!(out.len(), n);
    });
    let mut eng = FunctionalEngine::new(set.cfg, set.weights.clone());
    run("native/period_step_incremental_n484", 2, 20, || {
        let mut ph = ph0.clone();
        eng.period_step(&mut ph);
    });

    // --- RTL tick cost (the cycle-accurate fidelity price) ---
    let set76 = benchmark_by_name("7x6").unwrap();
    let mut ra = RecurrentOnn::new(set76.cfg, set76.weights.clone());
    ra.set_phases(&vec![0; set76.cfg.n]);
    run("rtl/recurrent_period_n42", 2, 50, || {
        for _ in 0..16 {
            ra.tick();
        }
    });

    // --- PJRT chunk execution (needs artifacts + the pjrt feature) ---
    #[cfg(feature = "pjrt")]
    {
        use onn_scale::runtime::artifact::{default_dir, Manifest};
        use onn_scale::runtime::engine::{PjrtContext, PjrtEngine};
        use onn_scale::runtime::ChunkEngine;
        if let Ok(manifest) = Manifest::load(&default_dir()) {
            let ctx = PjrtContext::cpu().expect("pjrt");
            for nn in [42usize, 484] {
                if let Some(info) = manifest.chunk_for(nn) {
                    let setn = if nn == 42 {
                        benchmark_by_name("7x6").unwrap()
                    } else {
                        benchmark_by_name("22x22").unwrap()
                    };
                    let mut pe = PjrtEngine::load(ctx.clone(), info).expect("load");
                    pe.set_weights(&setn.weights.to_f32()).unwrap();
                    let b = info.batch;
                    let mut phases: Vec<i32> =
                        (0..b * nn).map(|_| rng.range_i64(0, 16) as i32).collect();
                    let mut settled = vec![-1i32; b];
                    let name = format!(
                        "pjrt/chunk16_n{nn}_b{b} ({} trials-periods/call)",
                        b * info.chunk
                    );
                    run(&name, 2, 10, || {
                        pe.run_chunk(&mut phases, &mut settled, 0).unwrap();
                    });
                }
            }
        } else {
            println!("(artifacts missing; skipping pjrt benches — run `make artifacts`)");
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("(pjrt feature disabled; skipping pjrt benches)");

    // --- solver portfolio hot path (the optimization job class) ---
    {
        use onn_scale::solver::graph::Graph;
        use onn_scale::solver::portfolio::{solve_native, PortfolioParams};
        use onn_scale::solver::reductions::max_cut;
        let mut srng = Rng::new(77);
        let g = Graph::random(64, 0.1, &mut srng);
        let problem = max_cut(&g);
        let params = PortfolioParams {
            replicas: 32,
            max_periods: 128,
            plateau_chunks: 0,
            ..Default::default()
        };
        run("solver/portfolio_maxcut_n64_r32_p128", 1, 5, || {
            let out = solve_native(&problem, &params).expect("portfolio");
            assert!(out.best_energy <= out.initial_best_energy);
        });
    }

    // --- coordinator end-to-end throughput, native pool, 1 vs N workers ---
    let set = benchmark_by_name("7x6").unwrap();
    let p = set.cfg.period() as i32;
    for workers in [1usize, 4] {
        let coord = Arc::new(
            Coordinator::start(
                vec![PoolSpec::new(set.cfg, set.weights.clone(), EngineKind::Native)
                    .with_workers(workers)],
                BatchPolicy {
                    max_wait: Duration::from_millis(1),
                    max_periods_cap: 256,
                },
            )
            .unwrap(),
        );
        let name = format!("coordinator/100_retrievals_7x6_native_w{workers}");
        run(&name, 1, 5, || {
            let mut pending = Vec::new();
            let mut rng = Rng::new(9);
            for i in 0..100 {
                let target = &set.dataset.patterns[i % 5];
                let corrupted = target.corrupt(10, &mut rng);
                let req =
                    RetrievalRequest::from_pattern(coord.next_id(), &corrupted, p, 256);
                pending.push(coord.router.submit(req).unwrap());
            }
            for rx in pending {
                let _ = rx.recv().unwrap();
            }
        });
        let snap = coord.snapshot();
        println!(
            "  workers={workers}: {} jobs, {} batches, mean occupancy {:.1}",
            snap.completed, snap.batches, snap.mean_occupancy
        );
    }
}
