//! Minimal offline stand-in for the `anyhow` crate.
//!
//! This image has no crates.io access, so the workspace vendors the
//! subset of anyhow it actually uses: [`Error`], [`Result`], the
//! [`anyhow!`] macro, and the [`Context`] extension trait.  Semantics
//! match the real crate for these paths:
//!
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`;
//! * `{e}` prints the outermost message, `{e:#}` the whole cause chain
//!   joined by `": "`;
//! * `context`/`with_context` wrap an error with a higher-level message.
//!
//! A production checkout can swap this path dependency for the real
//! `anyhow` without touching any source file.

use std::error::Error as StdError;
use std::fmt;

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error with an optional cause chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from a displayable message (no cause).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Wrap a concrete error value.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        Error {
            msg: error.to_string(),
            source: Some(Box::new(error)),
        }
    }

    /// Wrap this error with a higher-level message (cause chain grows).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error {
            msg: context.to_string(),
            source: Some(Box::new(Chained {
                msg: self.msg,
                source: self.source,
            })),
        }
    }

    /// The outermost message.
    pub fn to_string_outer(&self) -> &str {
        &self.msg
    }

    /// Root-to-leaf iteration of the cause chain messages.
    fn write_chain(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur: Option<&(dyn StdError + 'static)> = self
            .source
            .as_ref()
            .map(|b| &**b as &(dyn StdError + 'static));
        while let Some(e) = cur {
            write!(f, ": {e}")?;
            cur = e.source();
        }
        Ok(())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            self.write_chain(f)
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.write_chain(f)
    }
}

/// Internal link type so a wrapped [`Error`] can live in a
/// `dyn std::error::Error` cause chain ([`Error`] itself deliberately
/// does not implement `std::error::Error`, to keep the blanket `From`).
struct Chained {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl fmt::Display for Chained {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Chained {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl StdError for Chained {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.source
            .as_ref()
            .map(|b| &**b as &(dyn StdError + 'static))
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// Extension trait adding `context`/`with_context` to `Result`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

/// `anyhow!(...)`: build an [`Error`] from a format string or any
/// displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)+) => {
        $crate::Error::msg(format!($fmt, $($arg)+))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "missing file");
    }

    #[test]
    fn macro_forms() {
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
        let n = 3;
        let e = anyhow!("n={n}");
        assert_eq!(format!("{e}"), "n=3");
        let e = anyhow!("a {} b {}", 1, 2);
        assert_eq!(format!("{e}"), "a 1 b 2");
        let e = anyhow!(String::from("owned"));
        assert_eq!(format!("{e}"), "owned");
    }

    #[test]
    fn alternate_prints_cause_chain() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading manifest".to_string())
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing file");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
