//! Offline stub of the `xla` crate.
//!
//! The image building this workspace has no XLA/PJRT toolchain, so the
//! `pjrt` cargo feature resolves to this stub: the exact API surface
//! `runtime::engine` uses, with every entry point that would touch PJRT
//! returning an "unavailable" error.  `PjRtClient::cpu()` fails, so no
//! other method is ever reached at runtime; they exist only so the
//! engine code type-checks under `--features pjrt`.
//!
//! A production checkout points the `xla` path dependency at the real
//! crate instead; no source file changes.

/// Stub error: always "PJRT unavailable".
pub struct Error(&'static str);

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str =
    "xla stub: PJRT unavailable in this offline build (vendored rust/vendor/xla); \
     point the `xla` path dependency at the real crate to run artifacts";

fn unavailable<T>() -> Result<T> {
    Err(Error(UNAVAILABLE))
}

/// PJRT client handle (never constructible in the stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Parsed HLO module (never constructible in the stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable (never constructible in the stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Host literal.
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T>(_value: T) -> Literal {
        Literal
    }

    pub fn reshape(self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}
