//! End-to-end driver (EXPERIMENTS.md "End-to-end validation"): the full
//! three-layer stack on a real workload.
//!
//! Trains the 22x22 letter dataset (484 fully connected oscillators —
//! the paper's headline scale), loads the AOT-compiled JAX/Pallas chunk
//! artifact through PJRT, and pushes hundreds of corrupted patterns
//! through the coordinator (router -> dynamic batcher -> engine worker),
//! reporting retrieval accuracy, settle times, service latency and
//! throughput, plus a Figure-8-style ASCII rendering.
//!
//! Run: `make artifacts && cargo run --release --example pattern_retrieval`
//! (falls back to the bit-exact native engine if artifacts are absent).

use std::time::{Duration, Instant};

use onn_scale::coordinator::batcher::BatchPolicy;
use onn_scale::coordinator::job::RetrievalRequest;
use onn_scale::coordinator::server::{Coordinator, EngineKind, PoolSpec};
use onn_scale::harness::datasets::benchmark_by_name;
use onn_scale::onn::patterns::Pattern;
use onn_scale::onn::phase::state_to_spins;
use onn_scale::runtime::artifact::{default_dir, Manifest};
use onn_scale::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let trials_per_pattern = 40;
    let corruption_levels = [10.0, 25.0, 50.0];

    println!("== onn-scale end-to-end: 22x22 pattern retrieval ==\n");
    let t0 = Instant::now();
    let set = benchmark_by_name("22x22").expect("dataset");
    println!(
        "trained DO-I weights for {} patterns on n={} in {:.2} s ({} epochs)",
        set.dataset.patterns.len(),
        set.cfg.n,
        t0.elapsed().as_secs_f64(),
        set.doi_epochs
    );

    let kind = match Manifest::load(&default_dir()) {
        Ok(m) if m.chunk_for(set.cfg.n).is_some() => EngineKind::Pjrt,
        _ => {
            println!("(no AOT artifact found for n={}; using native engine)", set.cfg.n);
            EngineKind::Native
        }
    };
    println!("engine: {kind:?}\n");

    let coord = Coordinator::start(
        vec![PoolSpec::new(set.cfg, set.weights.clone(), kind)],
        BatchPolicy {
            max_wait: Duration::from_millis(3),
            max_periods_cap: 256,
        },
    )?;

    let p = set.cfg.period() as i32;
    let mut example_render: Option<(Pattern, Pattern, Pattern)> = None;

    for pct in corruption_levels {
        let mut rng = Rng::new(2025 + pct as u64);
        let start = Instant::now();
        let mut pending = Vec::new();
        for target in &set.dataset.patterns {
            let flips = target.corruption_count(pct);
            for _ in 0..trials_per_pattern {
                let corrupted = target.corrupt(flips, &mut rng);
                let req =
                    RetrievalRequest::from_pattern(coord.next_id(), &corrupted, p, 256);
                pending.push((target.clone(), corrupted, coord.router.submit(req)?));
            }
        }
        let total = pending.len();
        let mut correct = 0usize;
        let mut settles = Vec::new();
        for (target, corrupted, rx) in pending {
            let res = rx.recv()?;
            let spins = state_to_spins(&res.phases, p);
            let ok = res.settled.is_some() && target.matches_up_to_inversion(&spins);
            if ok {
                correct += 1;
                if let Some(s) = res.settled {
                    settles.push(s as f64);
                }
                if example_render.is_none() && pct == 25.0 {
                    let flip = if target.overlap(&spins) < 0.0 { -1 } else { 1 };
                    let retrieved = Pattern {
                        name: "retrieved".into(),
                        rows: target.rows,
                        cols: target.cols,
                        spins: spins.iter().map(|&s| s * flip).collect(),
                    };
                    example_render = Some((target.clone(), corrupted, retrieved));
                }
            }
        }
        let dt = start.elapsed().as_secs_f64();
        println!(
            "corruption {pct:>4.0}%: accuracy {:>5.1}%  mean settle {:>5.1} periods  \
             {:>6.1} retrievals/s  ({total} trials in {dt:.2} s)",
            100.0 * correct as f64 / total as f64,
            onn_scale::util::stats::mean(&settles),
            total as f64 / dt,
        );
    }

    let snap = coord.snapshot();
    println!(
        "\nservice metrics: {} jobs, {} batches, mean occupancy {:.1}, \
         mean queue {:.2} ms, mean latency {:.2} ms",
        snap.completed, snap.batches, snap.mean_occupancy, snap.mean_queue_ms, snap.mean_total_ms
    );

    if let Some((target, corrupted, retrieved)) = example_render {
        println!("\nFigure-8-style example (target | corrupted 25% | retrieved):\n");
        let (t, c, r) = (target.render(), corrupted.render(), retrieved.render());
        for ((a, b), c) in t.lines().zip(c.lines()).zip(r.lines()) {
            println!("  {a}   {b}   {c}");
        }
    }

    coord.shutdown()?;
    println!("\ndone.");
    Ok(())
}
