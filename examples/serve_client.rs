//! Service demo: starts the coordinator with a TCP JSON-lines front-end
//! (the stand-in for the paper's laptop-UI -> PYNQ link), connects as a
//! client, and round-trips corrupted-pattern retrievals over the socket.
//!
//! Run: `cargo run --release --example serve_client`

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use onn_scale::coordinator::batcher::BatchPolicy;
use onn_scale::coordinator::server::{serve_tcp, Coordinator, EngineKind, PoolSpec};
use onn_scale::harness::datasets::benchmark_by_name;
use onn_scale::onn::phase::spin_to_phase;
use onn_scale::util::json::Json;
use onn_scale::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let set = benchmark_by_name("7x6").expect("dataset");
    let coord = Coordinator::start(
        vec![PoolSpec::new(set.cfg, set.weights.clone(), EngineKind::Native)],
        BatchPolicy {
            max_wait: Duration::from_millis(2),
            max_periods_cap: 256,
        },
    )?;

    // Bind on an ephemeral port and serve in the background.
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let router = Arc::clone(&coord.router);
    std::thread::spawn(move || {
        let _ = serve_tcp(router, listener);
    });
    println!("coordinator serving 7x6 dataset on {addr}\n");

    // --- client side: JSON lines over the socket ---
    let stream = TcpStream::connect(addr)?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut rng = Rng::new(11);
    let p = set.cfg.period() as i32;

    for (id, target) in set.dataset.patterns.iter().enumerate() {
        let corrupted = target.corrupt(target.corruption_count(25.0), &mut rng);
        let phases: Vec<i32> = corrupted
            .spins
            .iter()
            .map(|&s| spin_to_phase(s, p))
            .collect();
        let req = Json::obj(vec![
            ("id", Json::num(id as f64)),
            ("n", Json::num(set.cfg.n as f64)),
            ("phases", Json::arr_i32(&phases)),
            ("max_periods", Json::num(256.0)),
        ]);
        writer.write_all(req.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let resp = Json::parse(line.trim()).expect("valid response json");
        let settled = resp.get("settled").cloned().unwrap_or(Json::Null);
        println!(
            "pattern '{}': request {} -> settled = {}",
            target.name,
            id,
            settled
        );
    }

    println!("\nservice snapshot: {:?}", coord.snapshot());
    drop(reader);
    drop(writer);
    coord.shutdown()?;
    Ok(())
}
