//! The generic Ising solver end-to-end: reduce three problem families
//! onto the `solver` IR, run the annealed batched replica portfolio on
//! the native chunk engine, and compare against classical baselines —
//! then serve the same max-cut instance through the coordinator's
//! JSON-lines `SolveRequest` path, the way optimization traffic reaches
//! a deployed ONN service.
//!
//! Run: `cargo run --release --example ising_portfolio`

use onn_scale::coordinator::batcher::BatchPolicy;
use onn_scale::coordinator::job::SolveRequest;
use onn_scale::coordinator::server::Coordinator;
use onn_scale::solver::anneal::Schedule;
use onn_scale::solver::graph::Graph;
use onn_scale::solver::portfolio::{
    solve_native, solve_with, solve_with_trace, EngineSelect, PortfolioParams,
};
use onn_scale::solver::{reductions, sa};
use onn_scale::telemetry::{sink, TraceEvent, DEFAULT_TRACE_CAP};
use onn_scale::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(42);

    // --- 1. max-cut: annealed portfolio vs SA at equal spin updates ---
    println!("== max-cut: annealed ONN portfolio vs simulated annealing ==");
    println!(
        "  {:>6} {:>7} {:>9} {:>9} {:>8}",
        "nodes", "edges", "ONN cut", "SA cut", "ratio"
    );
    for &n in &[16, 32, 64] {
        let g = Graph::random(n, 0.25, &mut rng);
        let problem = reductions::max_cut(&g);
        let params = PortfolioParams {
            replicas: 24,
            max_periods: 128,
            schedule: Schedule::Geometric {
                start: 0.5,
                factor: 0.8,
            },
            seed: 1000 + n as u64,
            ..Default::default()
        };
        let onn = solve_native(&problem, &params).expect("portfolio");
        let onn_cut = g.cut_value(&onn.best_spins);
        let base = sa::anneal(&problem, 24 * 128, 2000 + n as u64);
        let sa_cut = g.cut_value(&base.spins);
        println!(
            "  {:>6} {:>7} {:>9} {:>9} {:>8.3}",
            n,
            g.edges.len(),
            onn_cut,
            sa_cut,
            onn_cut as f64 / sa_cut.max(1) as f64
        );
    }

    // --- 2. number partitioning: a non-graph reduction ---
    let weights: Vec<i64> = (0..20).map(|_| rng.range_i64(1, 50)).collect();
    let problem = reductions::number_partition(&weights);
    let out = solve_native(&problem, &PortfolioParams::default()).expect("portfolio");
    println!(
        "\n== number partitioning == 20 numbers, total {}: imbalance {}",
        weights.iter().sum::<i64>(),
        reductions::partition_imbalance(&weights, &out.best_spins)
    );

    // --- 3. minimum vertex cover: fields -> ancilla embedding ---
    let g = Graph::random(24, 0.15, &mut rng);
    let problem = reductions::min_vertex_cover(&g, 2.0);
    let out = solve_native(&problem, &PortfolioParams::default()).expect("portfolio");
    let cover = reductions::decode_cover(&g, &out.best_spins);
    println!(
        "== min vertex cover == {} nodes / {} edges: cover size {} (valid: {})",
        g.n,
        g.edges.len(),
        reductions::cover_size(&cover),
        reductions::is_cover(&g, &cover)
    );

    // --- 4. one logical solve across a shard cluster ---
    // The row-sharded engine is bit-exact with the native one (noise
    // included): same seed, identical answer, but the rows — and the
    // per-period all-gather — are spread over 3 workers, the way a
    // multi-FPGA build exceeds one device's 506 oscillators.
    let g = Graph::random(48, 0.15, &mut rng);
    let problem = reductions::max_cut(&g);
    let params = PortfolioParams {
        replicas: 8,
        max_periods: 64,
        seed: 77,
        ..Default::default()
    };
    let native = solve_native(&problem, &params).expect("native solve");
    let sharded =
        solve_with(&problem, &params, EngineSelect::Sharded { shards: 3 }).expect("sharded solve");
    println!(
        "\n== sharded solve == n={} on 3 shards: cut {} (native {}), \
         bit-identical: {}, {} all-gather sync rounds",
        g.n,
        g.cut_value(&sharded.best_spins),
        g.cut_value(&native.best_spins),
        sharded.best_energy == native.best_energy && sharded.best_phases == native.best_phases,
        sharded.sync_rounds
    );

    // --- 5. the same solve on the bit-true emulated hardware ---
    // EngineSelect::Rtl runs the paper's serial-MAC hybrid datapath
    // cycle by cycle (5-bit weights, 4-bit phases, RTL settle
    // semantics) and prices the run in emulated fast-clock time — what
    // the programmed FPGA would take — next to the host simulation.
    let g = Graph::random(16, 0.3, &mut rng);
    let problem = reductions::max_cut(&g);
    let params = PortfolioParams {
        replicas: 8,
        max_periods: 64,
        seed: 78,
        ..Default::default()
    };
    let native = solve_native(&problem, &params).expect("native solve");
    let rtl = solve_with(&problem, &params, EngineSelect::Rtl).expect("rtl solve");
    let hw = rtl.hardware.as_ref().expect("rtl outcomes carry hardware cost");
    println!(
        "\n== bit-true rtl solve == n={}: cut {} (native {}), quantization \
         error {:.4}, {} fast cycles @ {:.1} MHz -> {:.3e} s emulated (fits \
         device: {})",
        g.n,
        g.cut_value(&rtl.best_spins),
        g.cut_value(&native.best_spins),
        rtl.quantization_error,
        hw.fast_cycles,
        hw.f_logic_mhz,
        hw.emulated_s,
        hw.fits_device
    );

    // --- 6. watching a solve converge through a trace sink ---
    // The telemetry recorder observes the lifecycle without perturbing
    // it: a traced run is bit-identical to an untraced one at equal
    // seed.  Grouping the per-chunk events by wave shows each wave's
    // best-energy trajectory — the same records `solve --trace FILE`
    // exports as JSONL and `"trace": true` attaches on the wire.
    let g = Graph::random(32, 0.2, &mut rng);
    let problem = reductions::max_cut(&g);
    let params = PortfolioParams {
        replicas: 8,
        max_periods: 64,
        seed: 79,
        ..Default::default()
    };
    let trace = sink(DEFAULT_TRACE_CAP);
    let traced = solve_with_trace(&problem, &params, EngineSelect::Native, Some(&trace))
        .expect("traced solve");
    let untraced = solve_native(&problem, &params).expect("untraced solve");
    println!(
        "\n== traced solve == n={}: energy {} over {} periods (tracing \
         perturbed nothing: {})",
        g.n,
        traced.best_energy,
        traced.periods,
        traced.best_energy == untraced.best_energy
            && traced.best_phases == untraced.best_phases
    );
    let rec = trace.borrow();
    let mut waves: Vec<(usize, Vec<f64>)> = Vec::new();
    for r in rec.records() {
        if let TraceEvent::Chunk {
            wave, best_energy, ..
        } = &r.event
        {
            match waves.last_mut() {
                Some((w, traj)) if w == wave => traj.push(*best_energy),
                _ => waves.push((*wave, vec![*best_energy])),
            }
        }
    }
    for (wave, traj) in &waves {
        let first = traj.first().copied().unwrap_or(0.0);
        let last = traj.last().copied().unwrap_or(first);
        println!(
            "  wave {wave}: {} chunks, running best energy {first:.1} -> {last:.1}",
            traj.len()
        );
    }
    println!("  ({} trace records, {} dropped to the ring)", rec.len(), rec.dropped());
    drop(rec);

    // --- 7. the same workload as service traffic ---
    println!("\n== coordinator: SolveRequest through the service stack ==");
    let coord = Coordinator::start(vec![], BatchPolicy::default()).expect("coordinator");
    let g = Graph::complete_bipartite(3, 3);
    let mut req = SolveRequest::new(coord.next_id(), reductions::max_cut(&g));
    req.replicas = 8;
    req.max_periods = 64;
    let res = coord.solve_sync(req).expect("solve");
    println!(
        "K(3,3) served: cut {} of 9, energy {}, {} replicas, {} engine, {:.2} ms",
        g.cut_value(&res.spins),
        res.energy,
        res.replicas,
        res.engine,
        res.total_latency.as_secs_f64() * 1e3
    );
    let snap = coord.snapshot();
    println!(
        "service: {} solves completed, mean {:.2} ms (p50 <= {:.3} ms, p99 <= \
         {:.3} ms), {} engine periods",
        snap.solves_completed,
        snap.mean_solve_ms,
        snap.solve.p50_ms,
        snap.solve.p99_ms,
        snap.solve_periods
    );
    coord.shutdown().expect("shutdown");
}
