//! ONN-as-Ising-machine: max-cut on random graphs, ONN vs simulated
//! annealing — the application class the paper's Discussion targets for
//! the scaled-up hybrid architecture.
//!
//! Run: `cargo run --release --example maxcut`

use onn_scale::apps::maxcut::{solve_onn, solve_sa, Graph};
use onn_scale::util::rng::Rng;

fn main() {
    println!("== max-cut: ONN relaxation vs simulated annealing ==\n");
    println!(
        "  {:>6} {:>7} {:>9} {:>9} {:>8}",
        "nodes", "edges", "ONN cut", "SA cut", "ratio"
    );
    let mut rng = Rng::new(42);
    for &n in &[16, 32, 64, 128, 256] {
        let g = Graph::random(n, 0.25, &mut rng);
        let onn = solve_onn(&g, 20, 128, 1000 + n as u64);
        let sa = solve_sa(&g, 300, 2000 + n as u64);
        println!(
            "  {:>6} {:>7} {:>9} {:>9} {:>8.3}",
            n,
            g.edges.len(),
            onn.cut,
            sa.cut,
            onn.cut as f64 / sa.cut.max(1) as f64
        );
    }
    println!(
        "\nBipartite sanity check (exact optimum known): ONN must find the full cut."
    );
    let g = Graph {
        n: 8,
        edges: (0..4)
            .flat_map(|i| (4..8).map(move |j| (i, j, 1)))
            .collect(),
    };
    let res = solve_onn(&g, 10, 64, 7);
    println!(
        "K(4,4): optimum 16, ONN found {} -> {}",
        res.cut,
        if res.cut == 16 { "OK" } else { "SUBOPTIMAL" }
    );
}
