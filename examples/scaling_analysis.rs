//! Hardware-scaling walkthrough: regenerates the paper's entire scaling
//! story (Tables 1/2/4/5, Figures 9-12) from the structural FPGA model,
//! and adds a what-if sweep over other devices and precisions that the
//! paper's Discussion motivates.
//!
//! Run: `cargo run --release --example scaling_analysis`

use onn_scale::fpga::device::{kintex7_325t, zynq7010, zynq7020};
use onn_scale::fpga::resources::max_oscillators;
use onn_scale::harness::report;

fn main() {
    println!("{}", report::table1());
    println!("{}", report::table2());
    println!("{}", report::table4());
    println!("{}", report::table5());
    println!("{}", report::fig9());
    println!("{}", report::fig10());
    println!("{}", report::fig11());
    println!("{}", report::fig12());

    // --- extension: capacity on other devices / precisions ---
    println!("What-if: max fully connected oscillators by device and precision");
    println!("(hybrid architecture; paper precision is 5 weight bits / 4 phase bits)\n");
    println!(
        "  {:<16} {:>10} {:>10} {:>10}",
        "device", "5wb/4pb", "4wb/4pb", "6wb/5pb"
    );
    for dev in [zynq7010(), zynq7020(), kintex7_325t()] {
        let a = max_oscillators("hybrid", &dev, 4, 5);
        let b = max_oscillators("hybrid", &dev, 4, 4);
        let c = max_oscillators("hybrid", &dev, 5, 6);
        println!("  {:<16} {:>10} {:>10} {:>10}", dev.name, a, b, c);
    }
    println!();
    println!(
        "  recurrent on {}: {} oscillators (the paper's 10.5x headline is\n  \
         the ratio of the first column to this number)",
        zynq7020().name,
        max_oscillators("recurrent", &zynq7020(), 4, 5)
    );
}
