//! Quickstart: the whole pipeline on the 3x3 dataset in ~40 lines of
//! API — train DO-I weights, corrupt a pattern, retrieve it with the
//! functional engine, and peek at the underlying shift-register
//! oscillator (paper Table 3).
//!
//! Run: `cargo run --release --example quickstart`

use onn_scale::onn::config::NetworkConfig;
use onn_scale::onn::dynamics::FunctionalEngine;
use onn_scale::onn::learning::train_quantized;
use onn_scale::onn::patterns::dataset_3x3;
use onn_scale::onn::phase::{spin_to_phase, state_to_spins};
use onn_scale::rtl::oscillator::ShiftRegOscillator;
use onn_scale::util::rng::Rng;

fn main() {
    // --- the phase-controlled oscillator itself (paper Table 3) ---
    println!("Circular shift-register oscillator, 2 phase bits:");
    let mut osc = ShiftRegOscillator::new(4);
    for t in 0..5 {
        println!("  t={t}  registers={:?}", osc.state());
        osc.tick();
    }
    println!();

    // --- train the 3x3 associative memory ---
    let ds = dataset_3x3();
    let cfg = NetworkConfig::paper(ds.n());
    let pats: Vec<Vec<i8>> = ds.patterns.iter().map(|p| p.spins.clone()).collect();
    let weights = train_quantized(&pats, &cfg);
    println!(
        "trained {} patterns into a {}-oscillator ONN ({} weight bits, {} phase bits)\n",
        pats.len(),
        cfg.n,
        cfg.weight_bits,
        cfg.phase_bits
    );

    // --- corrupt and retrieve each pattern ---
    let mut engine = FunctionalEngine::new(cfg, weights);
    let mut rng = Rng::new(7);
    let p = cfg.period() as i32;
    for target in &ds.patterns {
        let corrupted = target.corrupt(2, &mut rng);
        let init: Vec<i32> = corrupted
            .spins
            .iter()
            .map(|&s| spin_to_phase(s, p))
            .collect();
        let out = engine.run_to_settle(&init, 256);
        let spins = state_to_spins(&out.phases, p);
        let ok = target.matches_up_to_inversion(&spins);
        println!(
            "pattern '{}': settled after {:?} periods, retrieved: {}",
            target.name,
            out.settled,
            if ok { "OK" } else { "WRONG" }
        );
        let retrieved = onn_scale::onn::patterns::Pattern {
            name: "retrieved".into(),
            rows: target.rows,
            cols: target.cols,
            // align sign to the target for display
            spins: {
                let flip = if target.overlap(&spins) < 0.0 { -1 } else { 1 };
                spins.iter().map(|&s| s * flip).collect()
            },
        };
        for (l, (a, b)) in target
            .render()
            .lines()
            .zip(corrupted.render().lines().map(String::from).collect::<Vec<_>>())
            .enumerate()
        {
            let c = retrieved.render().lines().nth(l).unwrap_or("").to_string();
            println!("  {a}   {b}   {c}");
        }
        println!("  (target | corrupted | retrieved)\n");
    }
}
